//! Deterministic fault injection for the serving stack.
//!
//! Edge deployments fail in ways unit tests rarely exercise: an engine
//! starts erroring, a worker panics mid-batch, a kernel stalls, the queue
//! backs up.  This module is the single switchboard those failures are
//! *injected* through, so the chaos suite (`rust/tests/test_chaos.rs`) can
//! drive the real server through overload + crashes and assert the
//! fault-tolerance layer (bounded admission, engine quarantine, supervised
//! worker) actually degrades gracefully.
//!
//! Three properties the design guarantees:
//!
//! * **Zero cost when disarmed.**  Every hook fast-paths on one relaxed
//!   atomic load ([`armed`]); with `PALLAS_FAULTS` unset nothing else runs —
//!   no RNG, no lock, no allocation — and the engine-level faults are not
//!   even wired in (the server only wraps roster engines in
//!   [`crate::runtime::engine::FaultInjector`] when armed at build time).
//! * **Deterministic.**  All decisions come from one seeded [`Rng`]
//!   consumed behind a mutex.  The serving hooks are consulted only from
//!   the single inference-worker thread (engine faults per forward, queue
//!   stalls per pop), so a fixed request sequence yields the same fault
//!   sequence on every run — including under `PALLAS_POOL_THREADS=1` vs
//!   the default pool, which only changes row-band parallelism *inside* a
//!   bitwise-deterministic kernel call.  The CI chaos gate runs the suite
//!   under both pool configurations with the same seed and the outcomes
//!   must match.
//! * **Armed explicitly.**  Either programmatically ([`arm`]/[`disarm`],
//!   what the tests do) or via the `PALLAS_FAULTS` environment variable
//!   ([`arm_from_env`], called once at server startup).
//!
//! ## `PALLAS_FAULTS` grammar
//!
//! Semicolon-separated `key=value` clauses:
//!
//! ```text
//! PALLAS_FAULTS="seed=7;engine.error=host-csd:0.5;engine.panic=*:0.05;
//!                engine.delay=host-f32:0.2:25;queue.stall=0.1:10;
//!                link.burst=0.01:0.25:0.02"
//! ```
//!
//! | clause | value | meaning |
//! |---|---|---|
//! | `seed` | `u64` | RNG seed (default 0) |
//! | `engine.error` | `NAME:PROB` | `forward_with` on engine `NAME` returns an error with probability `PROB` (`NAME` may be `*`) |
//! | `engine.panic` | `NAME:PROB` | `forward_with` panics instead |
//! | `engine.delay` | `NAME:PROB:MS` | a latency spike of `MS` milliseconds before the forward |
//! | `queue.stall` | `PROB:MS` | the batch pop stalls `MS` milliseconds (simulates a wedged consumer) |
//! | `link.burst` | `ENTER:EXIT:BER` | arms a Gilbert–Elliott burst profile ([`crate::channel::link::BurstConfig`]) that `deploy-sim` and the hot-swap pipeline apply to their links |
//! | `swap.build` | `PROB` | the hot-swap pipeline's engine-build stage fails ([`crate::coordinator::swap`]) |
//! | `swap.canary` | `PROB` | the hot-swap canary gate reports divergence and rejects the staged generation |
//!
//! Each clause kind may repeat (e.g. different probabilities per engine).
//! Probabilities are validated to `[0, 1]`; a malformed spec fails server
//! startup loudly rather than silently running fault-free.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::util::rng::Rng;

/// What an armed engine hook decided for one forward.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Action {
    /// Return an error from `forward_with`.
    Error,
    /// Panic inside `forward_with` (exercises the supervised worker).
    Panic,
    /// Sleep this long, then forward normally (latency spike).
    Delay(Duration),
}

/// A parsed fault specification (see the module docs for the grammar).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    /// Seed for the decision RNG.
    pub seed: u64,
    /// `(engine name or "*", probability)` — injected `forward_with` errors.
    pub engine_error: Vec<(String, f64)>,
    /// `(engine name or "*", probability)` — injected panics.
    pub engine_panic: Vec<(String, f64)>,
    /// `(engine name or "*", probability, millis)` — latency spikes.
    pub engine_delay: Vec<(String, f64, u64)>,
    /// `(probability, millis)` — batch-pop stalls.
    pub queue_stall: Option<(f64, u64)>,
    /// `(p_enter, p_exit, ber_bad)` — Gilbert–Elliott burst profile for the
    /// channel link (consumed by `deploy-sim` and the hot-swap pipeline,
    /// not by the serving hooks).
    pub link_burst: Option<(f64, f64, f64)>,
    /// Probability that the hot-swap engine-build stage fails.
    pub swap_build: Option<f64>,
    /// Probability that the hot-swap canary gate reports divergence.
    pub swap_canary: Option<f64>,
}

fn parse_prob(s: &str) -> Result<f64> {
    let p: f64 = s.parse().with_context(|| format!("bad probability {s:?}"))?;
    if !(0.0..=1.0).contains(&p) {
        bail!("probability {p} outside [0, 1]");
    }
    Ok(p)
}

impl FaultPlan {
    /// Parse the `PALLAS_FAULTS` grammar.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for clause in spec.split(';') {
            let clause = clause.trim();
            if clause.is_empty() {
                continue;
            }
            let (key, val) = clause
                .split_once('=')
                .with_context(|| format!("clause {clause:?} is not key=value"))?;
            let parts: Vec<&str> = val.split(':').collect();
            match (key.trim(), parts.as_slice()) {
                ("seed", [s]) => {
                    plan.seed = s.parse().with_context(|| format!("bad seed {s:?}"))?
                }
                ("engine.error", [name, p]) => {
                    plan.engine_error.push((name.to_string(), parse_prob(p)?))
                }
                ("engine.panic", [name, p]) => {
                    plan.engine_panic.push((name.to_string(), parse_prob(p)?))
                }
                ("engine.delay", [name, p, ms]) => plan.engine_delay.push((
                    name.to_string(),
                    parse_prob(p)?,
                    ms.parse().with_context(|| format!("bad delay ms {ms:?}"))?,
                )),
                ("queue.stall", [p, ms]) => {
                    plan.queue_stall = Some((
                        parse_prob(p)?,
                        ms.parse().with_context(|| format!("bad stall ms {ms:?}"))?,
                    ))
                }
                ("link.burst", [enter, exit, ber]) => {
                    plan.link_burst = Some((
                        parse_prob(enter)?,
                        parse_prob(exit)?,
                        parse_prob(ber)?,
                    ))
                }
                ("swap.build", [p]) => plan.swap_build = Some(parse_prob(p)?),
                ("swap.canary", [p]) => plan.swap_canary = Some(parse_prob(p)?),
                (k, _) => bail!("bad fault clause {k:?} = {val:?} (see util::faults docs)"),
            }
        }
        Ok(plan)
    }
}

/// Fast-path switch: every hook checks this before touching the plan state.
static ARMED: AtomicBool = AtomicBool::new(false);

struct Active {
    plan: FaultPlan,
    rng: Rng,
}

static STATE: Mutex<Option<Active>> = Mutex::new(None);

/// Whether fault injection is currently armed (one relaxed atomic load —
/// this is the entire hot-path cost of the fault layer when disarmed).
#[inline]
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed)
}

/// Arm fault injection with `plan` (replaces any previous plan and resets
/// the decision RNG to `plan.seed` — re-arming the same plan replays the
/// same decision sequence).
pub fn arm(plan: FaultPlan) {
    let rng = Rng::new(plan.seed);
    *STATE.lock().unwrap() = Some(Active { plan, rng });
    ARMED.store(true, Ordering::Release);
}

/// Disarm fault injection; all hooks revert to no-ops.
pub fn disarm() {
    ARMED.store(false, Ordering::Release);
    *STATE.lock().unwrap() = None;
}

/// Arm from the `PALLAS_FAULTS` environment variable if it is set and
/// nothing is armed yet.  Returns whether injection is armed afterwards;
/// a malformed spec is a hard error (failing loudly beats running a chaos
/// scenario fault-free).
pub fn arm_from_env() -> Result<bool> {
    if armed() {
        return Ok(true);
    }
    match std::env::var("PALLAS_FAULTS") {
        Ok(spec) if !spec.trim().is_empty() => {
            let plan = FaultPlan::parse(&spec)
                .with_context(|| format!("parsing PALLAS_FAULTS={spec:?}"))?;
            arm(plan);
            Ok(true)
        }
        _ => Ok(false),
    }
}

fn name_matches(pat: &str, engine: &str) -> bool {
    pat == "*" || pat == engine
}

/// The fault decision for one forward on `engine` (`None` = run normally).
/// Severity order: panic, then error, then delay — the first rule that
/// fires wins.  Only the inference worker thread calls this, so the
/// decision stream is a deterministic function of the seed and the
/// request sequence.
pub fn engine_action(engine: &str) -> Option<Action> {
    if !armed() {
        return None;
    }
    let mut g = STATE.lock().unwrap();
    let Active { plan, rng } = g.as_mut()?;
    for (pat, p) in &plan.engine_panic {
        if name_matches(pat, engine) && rng.chance(*p) {
            return Some(Action::Panic);
        }
    }
    for (pat, p) in &plan.engine_error {
        if name_matches(pat, engine) && rng.chance(*p) {
            return Some(Action::Error);
        }
    }
    for (pat, p, ms) in &plan.engine_delay {
        if name_matches(pat, engine) && rng.chance(*p) {
            return Some(Action::Delay(Duration::from_millis(*ms)));
        }
    }
    None
}

/// An injected batch-pop stall, if one fires (`None` = pop normally).
pub fn queue_stall() -> Option<Duration> {
    if !armed() {
        return None;
    }
    let mut g = STATE.lock().unwrap();
    let Active { plan, rng } = g.as_mut()?;
    let (p, ms) = plan.queue_stall?;
    rng.chance(p).then(|| Duration::from_millis(ms))
}

/// The armed Gilbert–Elliott burst profile for the channel link, if any.
/// Unlike the serving hooks this is configuration, not a per-call decision
/// (the link has its own seeded RNG), so it draws nothing from the fault
/// RNG.
pub fn link_burst() -> Option<crate::channel::link::BurstConfig> {
    if !armed() {
        return None;
    }
    let g = STATE.lock().unwrap();
    let (p_enter, p_exit, ber_bad) = g.as_ref()?.plan.link_burst?;
    Some(crate::channel::link::BurstConfig { p_enter, p_exit, ber_bad })
}

/// One fault decision for a hot-swap stage.  Certainties (`p <= 0` or
/// `p >= 1`) never touch the decision RNG: swap stages run on the *deploy*
/// thread, concurrently with the inference worker, and a deploy-side draw
/// would perturb the worker's deterministic fault stream.  The chaos suite
/// only arms swap clauses at 0 or 1, so determinism of the serving-side
/// sequence is preserved.
fn swap_stage_fires(pick: impl Fn(&FaultPlan) -> Option<f64>) -> bool {
    if !armed() {
        return false;
    }
    let mut g = STATE.lock().unwrap();
    let Some(active) = g.as_mut() else { return false };
    let Some(p) = pick(&active.plan) else { return false };
    if p >= 1.0 {
        true
    } else if p <= 0.0 {
        false
    } else {
        active.rng.chance(p)
    }
}

/// Whether the armed plan fails the hot-swap engine-build stage.
pub fn swap_build_fail() -> bool {
    swap_stage_fires(|p| p.swap_build)
}

/// Whether the armed plan makes the hot-swap canary gate report divergence.
pub fn swap_canary_fail() -> bool {
    swap_stage_fires(|p| p.swap_canary)
}

#[cfg(test)]
mod tests {
    use super::*;

    // Unit tests here only exercise the *parser* and plan equality — they
    // never arm the global switchboard, because `cargo test` runs tests
    // concurrently in one process and arming would leak faults into every
    // other suite.  Arm/disarm behavior is covered by the dedicated
    // `test_chaos` integration binary, which serializes access.

    #[test]
    fn parses_full_grammar() {
        let plan = FaultPlan::parse(
            "seed=42;engine.error=host-csd:0.5;engine.panic=*:0.05;\
             engine.delay=host-f32:0.2:25;queue.stall=0.1:10;link.burst=0.01:0.25:0.02;\
             swap.build=0.25;swap.canary=1.0",
        )
        .unwrap();
        assert_eq!(plan.seed, 42);
        assert_eq!(plan.engine_error, vec![("host-csd".to_string(), 0.5)]);
        assert_eq!(plan.engine_panic, vec![("*".to_string(), 0.05)]);
        assert_eq!(plan.engine_delay, vec![("host-f32".to_string(), 0.2, 25)]);
        assert_eq!(plan.queue_stall, Some((0.1, 10)));
        assert_eq!(plan.link_burst, Some((0.01, 0.25, 0.02)));
        assert_eq!(plan.swap_build, Some(0.25));
        assert_eq!(plan.swap_canary, Some(1.0));
    }

    #[test]
    fn clauses_may_repeat_and_whitespace_is_tolerated() {
        let plan =
            FaultPlan::parse(" engine.error=host-csd:1.0 ; engine.error=host-qgemm:0.5 ;;")
                .unwrap();
        assert_eq!(plan.engine_error.len(), 2);
        assert_eq!(plan.seed, 0, "seed defaults to 0");
    }

    #[test]
    fn rejects_malformed_specs() {
        for bad in [
            "engine.error=host-csd",      // missing probability
            "engine.error=host-csd:1.5",  // probability out of range
            "engine.delay=host-f32:0.2",  // missing millis
            "queue.stall=0.1:abc",        // non-numeric millis
            "swap.build=2.0",             // probability out of range
            "swap.canary=maybe",          // non-numeric probability
            "seed=notanumber",
            "unknown.site=1:0.5",
            "noequals",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn empty_spec_is_the_empty_plan() {
        assert_eq!(FaultPlan::parse("").unwrap(), FaultPlan::default());
    }

    #[test]
    fn disarmed_hooks_are_noops() {
        // nothing armed in this process (see module-test note above)
        assert!(!armed());
        assert_eq!(engine_action("host-csd"), None);
        assert_eq!(queue_stall(), None);
        assert!(link_burst().is_none());
        assert!(!swap_build_fail());
        assert!(!swap_canary_fail());
    }
}
