//! Minimal JSON parser/serializer (serde is not in the offline crate set).
//!
//! Supports the full JSON grammar minus exotic number forms; numbers are kept
//! as f64 (adequate for `artifacts/manifest.json` and protocol messages).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Path access: `v.get("a").get("b")` style, returning Null on miss.
    pub fn get(&self, key: &str) -> &Value {
        static NULL: Value = Value::Null;
        match self {
            Value::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
    pub fn idx(&self, i: usize) -> &Value {
        static NULL: Value = Value::Null;
        match self {
            Value::Arr(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
    pub fn is_null(&self) -> bool {
        matches!(self, Value::Null)
    }

    /// Serialize to a compact JSON string.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        self.write_to(&mut s);
        s
    }

    fn write_to(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => write_escaped(s, out),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write_to(out);
                }
                out.push(']');
            }
            Value::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write_to(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
pub fn num(n: f64) -> Value {
    Value::Num(n)
}
pub fn s(v: &str) -> Value {
    Value::Str(v.to_string())
}
pub fn arr(v: Vec<Value>) -> Value {
    Value::Arr(v)
}

pub fn parse(input: &str) -> Result<Value, String> {
    let mut p = Parser { b: input.as_bytes(), i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Value, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Value, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|t| t.parse::<f64>().ok())
            .map(Value::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let cp =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 char
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf8".to_string())?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(out));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Value, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(out));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(parse("\"hi\\n\"").unwrap(), Value::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").idx(2).get("b").as_str(), Some("c"));
        assert!(v.get("d").is_null());
        assert!(v.get("missing").is_null());
    }

    #[test]
    fn roundtrip() {
        let v = obj(vec![
            ("x", num(1.5)),
            ("y", arr(vec![s("a"), Value::Bool(false), Value::Null])),
            ("z", obj(vec![("k", num(42.0))])),
        ]);
        let text = v.to_json();
        assert_eq!(parse(&text).unwrap(), v);
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Value::Str("quote\" slash\\ nl\n tab\t".into());
        assert_eq!(parse(&v.to_json()).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap(), Value::Str("A".into()));
    }

    #[test]
    fn errors() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(num(3.0).to_json(), "3");
        assert_eq!(num(3.25).to_json(), "3.25");
    }
}
