//! Tiny CLI argument parser (clap is not in the offline crate set).
//!
//! Grammar: `qsq-edge <subcommand> [--flag] [--key value] [positional...]`.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: String,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                out.subcommand = it.next().unwrap();
            }
        }
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                // --key=value or --key value or --flag
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    out.options.insert(key.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(key.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()))
    }

    #[test]
    fn parses_subcommand_and_options() {
        // note: a bare `--x v` pair always binds as option=value; flags must
        // be last or followed by another `--` token (documented limitation)
        let a = args(&["repro", "extra", "--exp", "table3", "--fast"]);
        assert_eq!(a.subcommand, "repro");
        assert_eq!(a.get("exp"), Some("table3"));
        assert!(a.has_flag("fast"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn key_equals_value() {
        let a = args(&["serve", "--port=9000"]);
        assert_eq!(a.get_usize("port", 0), 9000);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = args(&["x", "--a", "--b", "v"]);
        assert!(a.has_flag("a"));
        assert_eq!(a.get("b"), Some("v"));
    }

    #[test]
    fn defaults() {
        let a = args(&["x"]);
        assert_eq!(a.get_or("missing", "d"), "d");
        assert_eq!(a.get_f64("missing", 1.5), 1.5);
    }

    #[test]
    fn no_subcommand() {
        let a = args(&["--help"]);
        assert_eq!(a.subcommand, "");
        assert!(a.has_flag("help"));
    }
}
