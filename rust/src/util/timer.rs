//! Wall-clock timing helpers.

use std::time::Instant;

/// Measure a closure's wall time in seconds.
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Simple stopwatch accumulating named spans (single-threaded use).
#[derive(Default)]
pub struct Stopwatch {
    spans: Vec<(String, f64)>,
}

impl Stopwatch {
    pub fn new() -> Self {
        Self::default()
    }
    pub fn measure<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let (out, dt) = time_it(f);
        self.spans.push((name.to_string(), dt));
        out
    }
    pub fn report(&self) -> String {
        let total: f64 = self.spans.iter().map(|(_, t)| t).sum();
        let mut out = String::new();
        for (name, t) in &self.spans {
            out.push_str(&format!(
                "{name:<30} {:>9.3} ms  ({:>5.1}%)\n",
                t * 1e3,
                if total > 0.0 { 100.0 * t / total } else { 0.0 }
            ));
        }
        out.push_str(&format!("{:<30} {:>9.3} ms\n", "TOTAL", total * 1e3));
        out
    }
    pub fn spans(&self) -> &[(String, f64)] {
        &self.spans
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_positive() {
        let (v, dt) = time_it(|| (0..1000).sum::<u64>());
        assert_eq!(v, 499500);
        assert!(dt >= 0.0);
    }

    #[test]
    fn stopwatch_accumulates() {
        let mut sw = Stopwatch::new();
        sw.measure("a", || std::thread::sleep(std::time::Duration::from_millis(1)));
        sw.measure("b", || ());
        assert_eq!(sw.spans().len(), 2);
        assert!(sw.report().contains("TOTAL"));
    }
}
