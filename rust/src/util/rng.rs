//! Deterministic PRNG (xoshiro256++ seeded via SplitMix64).
//!
//! Used everywhere randomness is needed — workload generation, channel bit
//! errors, property tests — so every run is reproducible from a single seed.

/// xoshiro256++ generator. Not cryptographic; fast, 2^256-1 period.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 (safe for any seed, including 0).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Panics if n == 0.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        // multiply-shift; bias negligible for our n << 2^64
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo) as u64 + 1) as i64
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            v.swap(i, j);
        }
    }

    /// Fork a statistically independent stream (for per-thread use).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(4);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            seen[r.below(10) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
