//! Reader/writer for NumPy `.npy` files (version 1.0/2.0, C-order,
//! little-endian) — the weight/dataset interchange with the python layer.

use std::fs;
use std::io::Write as _;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// Element types we exchange with the python layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    F64,
    I8,
    I32,
    I64,
}

impl DType {
    fn descr(self) -> &'static str {
        match self {
            DType::F32 => "<f4",
            DType::F64 => "<f8",
            DType::I8 => "|i1",
            DType::I32 => "<i4",
            DType::I64 => "<i8",
        }
    }
    fn size(self) -> usize {
        match self {
            DType::I8 => 1,
            DType::F32 | DType::I32 => 4,
            DType::F64 | DType::I64 => 8,
        }
    }
    fn from_descr(d: &str) -> Result<Self> {
        Ok(match d {
            "<f4" | "=f4" => DType::F32,
            "<f8" | "=f8" => DType::F64,
            "|i1" | "<i1" | "=i1" => DType::I8,
            "<i4" | "=i4" => DType::I32,
            "<i8" | "=i8" => DType::I64,
            other => bail!("unsupported npy dtype {other:?}"),
        })
    }
}

/// A loaded array: raw little-endian bytes + shape + dtype.
#[derive(Clone, Debug)]
pub struct Npy {
    pub dtype: DType,
    pub shape: Vec<usize>,
    pub data: Vec<u8>,
}

impl Npy {
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn to_f32(&self) -> Result<Vec<f32>> {
        match self.dtype {
            DType::F32 => Ok(self
                .data
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect()),
            DType::F64 => Ok(self
                .data
                .chunks_exact(8)
                .map(|c| f64::from_le_bytes(c.try_into().unwrap()) as f32)
                .collect()),
            _ => bail!("npy: expected float data, got {:?}", self.dtype),
        }
    }

    pub fn to_i32(&self) -> Result<Vec<i32>> {
        match self.dtype {
            DType::I32 => Ok(self
                .data
                .chunks_exact(4)
                .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect()),
            DType::I64 => Ok(self
                .data
                .chunks_exact(8)
                .map(|c| i64::from_le_bytes(c.try_into().unwrap()) as i32)
                .collect()),
            DType::I8 => Ok(self.data.iter().map(|&b| b as i8 as i32).collect()),
            _ => bail!("npy: expected int data, got {:?}", self.dtype),
        }
    }

    pub fn to_i8(&self) -> Result<Vec<i8>> {
        match self.dtype {
            DType::I8 => Ok(self.data.iter().map(|&b| b as i8).collect()),
            _ => bail!("npy: expected i8 data, got {:?}", self.dtype),
        }
    }
}

pub fn read<P: AsRef<Path>>(path: P) -> Result<Npy> {
    let raw = fs::read(path.as_ref())
        .with_context(|| format!("reading {}", path.as_ref().display()))?;
    parse(&raw).with_context(|| format!("parsing {}", path.as_ref().display()))
}

pub fn parse(raw: &[u8]) -> Result<Npy> {
    if raw.len() < 10 || &raw[0..6] != b"\x93NUMPY" {
        bail!("not an npy file");
    }
    let major = raw[6];
    let (hlen, hstart) = match major {
        1 => (u16::from_le_bytes([raw[8], raw[9]]) as usize, 10),
        2 | 3 => (
            u32::from_le_bytes([raw[8], raw[9], raw[10], raw[11]]) as usize,
            12,
        ),
        v => bail!("unsupported npy version {v}"),
    };
    if hstart + hlen > raw.len() {
        bail!("npy header truncated: {} + {} > {}", hstart, hlen, raw.len());
    }
    let header = std::str::from_utf8(&raw[hstart..hstart + hlen])?;
    let descr = extract_str(header, "'descr'").context("npy header: descr")?;
    let dtype = DType::from_descr(&descr)?;
    let fortran = header.contains("'fortran_order': True");
    if fortran {
        bail!("fortran-order npy not supported");
    }
    let shape = extract_shape(header).context("npy header: shape")?;
    let data = raw[hstart + hlen..].to_vec();
    let expect = shape.iter().product::<usize>() * dtype.size();
    if data.len() < expect {
        bail!("npy data truncated: {} < {}", data.len(), expect);
    }
    Ok(Npy { dtype, shape, data: data[..expect].to_vec() })
}

fn extract_str(header: &str, key: &str) -> Option<String> {
    let at = header.find(key)? + key.len();
    let rest = &header[at..];
    let q0 = rest.find('\'')? + 1;
    let q1 = rest[q0..].find('\'')? + q0;
    Some(rest[q0..q1].to_string())
}

fn extract_shape(header: &str) -> Option<Vec<usize>> {
    let at = header.find("'shape'")? + 7;
    let rest = &header[at..];
    let p0 = rest.find('(')? + 1;
    let p1 = rest[p0..].find(')')? + p0;
    let inner = &rest[p0..p1];
    let mut out = Vec::new();
    for tok in inner.split(',') {
        let t = tok.trim();
        if t.is_empty() {
            continue;
        }
        out.push(t.parse().ok()?);
    }
    Some(out)
}

/// Write a .npy v1.0 file.
pub fn write<P: AsRef<Path>>(path: P, dtype: DType, shape: &[usize], data: &[u8]) -> Result<()> {
    let expect = shape.iter().product::<usize>() * dtype.size();
    if data.len() != expect {
        bail!("npy write: data len {} != shape product {}", data.len(), expect);
    }
    let shape_s = match shape.len() {
        0 => "()".to_string(),
        1 => format!("({},)", shape[0]),
        _ => format!(
            "({})",
            shape.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(", ")
        ),
    };
    let mut header = format!(
        "{{'descr': '{}', 'fortran_order': False, 'shape': {}, }}",
        dtype.descr(),
        shape_s
    );
    // pad so that data starts at a multiple of 64
    let unpadded = 10 + header.len() + 1;
    let pad = (64 - unpadded % 64) % 64;
    header.push_str(&" ".repeat(pad));
    header.push('\n');
    let mut f = fs::File::create(path)?;
    f.write_all(b"\x93NUMPY\x01\x00")?;
    f.write_all(&(header.len() as u16).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    f.write_all(data)?;
    Ok(())
}

pub fn write_f32<P: AsRef<Path>>(path: P, shape: &[usize], data: &[f32]) -> Result<()> {
    let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
    write(path, DType::F32, shape, &bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_f32() {
        let dir = std::env::temp_dir().join("qsq_npy_test");
        fs::create_dir_all(&dir).unwrap();
        let p = dir.join("a.npy");
        let data = [1.0f32, -2.5, 3.25, 0.0, 5.5, -6.125];
        write_f32(&p, &[2, 3], &data).unwrap();
        let a = read(&p).unwrap();
        assert_eq!(a.dtype, DType::F32);
        assert_eq!(a.shape, vec![2, 3]);
        assert_eq!(a.to_f32().unwrap(), data);
    }

    #[test]
    fn roundtrip_i8() {
        let dir = std::env::temp_dir().join("qsq_npy_test");
        fs::create_dir_all(&dir).unwrap();
        let p = dir.join("b.npy");
        let data = [0u8, 1, 2, 255, 128, 7];
        write(&p, DType::I8, &[6], &data).unwrap();
        let a = read(&p).unwrap();
        assert_eq!(a.to_i8().unwrap(), vec![0, 1, 2, -1, -128, 7]);
    }

    #[test]
    fn scalar_shape() {
        let dir = std::env::temp_dir().join("qsq_npy_test");
        fs::create_dir_all(&dir).unwrap();
        let p = dir.join("c.npy");
        write_f32(&p, &[], &[42.0]).unwrap();
        let a = read(&p).unwrap();
        assert_eq!(a.shape, Vec::<usize>::new());
        assert_eq!(a.to_f32().unwrap(), vec![42.0]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse(b"not npy at all").is_err());
    }

    #[test]
    fn data_starts_aligned() {
        // header layout matches numpy's 64-byte alignment convention
        let dir = std::env::temp_dir().join("qsq_npy_test");
        fs::create_dir_all(&dir).unwrap();
        let p = dir.join("d.npy");
        write_f32(&p, &[3], &[1.0, 2.0, 3.0]).unwrap();
        let raw = fs::read(&p).unwrap();
        let hlen = u16::from_le_bytes([raw[8], raw[9]]) as usize;
        assert_eq!((10 + hlen) % 64, 0);
    }
}
