//! From-scratch substrates: the offline crate universe is exactly the `xla`
//! dependency closure, so the conventional helpers (serde, rand, clap,
//! proptest, log) are implemented here instead (DESIGN.md §9).

pub mod cli;
pub mod faults;
pub mod json;
pub mod log;
pub mod npy;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod timer;
