//! Neural-net ops on [`Tensor`]: matmul, im2col conv (VALID/SAME), pooling,
//! softmax.  The im2col patch ordering is (di, dj, c) — identical to
//! `python/compile/kernels/ref.py::im2col` — so conv weights reshape the same
//! way on both sides.

use anyhow::{bail, Result};

use super::Tensor;

/// x [M,K] @ w [K,N] -> [M,N] on the blocked, scoped-thread-parallel kernel
/// ([`crate::kernels::blocked`]) — the host serving hot path.  Bitwise
/// identical to [`matmul_naive`] (same per-element reduction order).
pub fn matmul(x: &Tensor, w: &Tensor) -> Result<Tensor> {
    let (xs, ws) = (x.shape(), w.shape());
    if xs.len() != 2 || ws.len() != 2 || xs[1] != ws[0] {
        bail!("matmul shapes {:?} x {:?}", xs, ws);
    }
    let (m, k, n) = (xs[0], xs[1], ws[1]);
    let mut out = vec![0.0f32; m * n];
    crate::kernels::blocked::matmul_into(&mut out, x.data(), w.data(), m, k, n);
    Tensor::new(vec![m, n], out)
}

/// The original plain ikj loop with row-accumulation — kept as the oracle
/// the blocked/parallel kernel and the code-domain qgemm are tested against.
pub fn matmul_naive(x: &Tensor, w: &Tensor) -> Result<Tensor> {
    let (xs, ws) = (x.shape(), w.shape());
    if xs.len() != 2 || ws.len() != 2 || xs[1] != ws[0] {
        bail!("matmul shapes {:?} x {:?}", xs, ws);
    }
    let (m, k, n) = (xs[0], xs[1], ws[1]);
    let mut out = vec![0.0f32; m * n];
    let xd = x.data();
    let wd = w.data();
    for i in 0..m {
        let orow = &mut out[i * n..(i + 1) * n];
        for kk in 0..k {
            let a = xd[i * k + kk];
            if a == 0.0 {
                continue;
            }
            let wrow = &wd[kk * n..(kk + 1) * n];
            for j in 0..n {
                orow[j] += a * wrow[j];
            }
        }
    }
    Tensor::new(vec![m, n], out)
}

/// Add a bias vector [N] to every row of [M,N] (or broadcast over last dim).
/// Row-sliced vector adds — no per-element div/mod in the hot loop.
pub fn add_bias(x: &Tensor, b: &Tensor) -> Result<Tensor> {
    let n = *x.shape().last().unwrap_or(&0);
    if b.shape() != [n] {
        bail!("bias shape {:?} vs last dim {}", b.shape(), n);
    }
    let mut out = x.data().to_vec();
    if n > 0 {
        let bd = b.data();
        for row in out.chunks_exact_mut(n) {
            for (v, &bv) in row.iter_mut().zip(bd) {
                *v += bv;
            }
        }
    }
    Tensor::new(x.shape().to_vec(), out)
}

/// im2col for VALID conv: x [B,H,W,C], window kh x kw ->
/// ([B*H'*W', kh*kw*C], H', W') with (di, dj, c) ordering.
pub fn im2col(x: &Tensor, kh: usize, kw: usize) -> Result<(Tensor, usize, usize)> {
    let s = x.shape();
    if s.len() != 4 {
        bail!("im2col expects NHWC, got {:?}", s);
    }
    let (b, h, w, c) = (s[0], s[1], s[2], s[3]);
    if h < kh || w < kw {
        bail!("im2col window {kh}x{kw} larger than input {h}x{w}");
    }
    let (oh, ow) = (h - kh + 1, w - kw + 1);
    let kcols = kh * kw * c;
    let mut out = vec![0.0f32; b * oh * ow * kcols];
    im2col_rows_into(x.data(), (b, h, w, c), kh, kw, 0, b * oh * ow, &mut out);
    Ok((Tensor::new(vec![b * oh * ow, kcols], out)?, oh, ow))
}

/// Stage rows `[row0, row0+nrows)` of the VALID-conv patch matrix into
/// `dst` (`nrows * kh*kw*C` floats, fully overwritten) — the band-staging
/// primitive of the fused conv pipeline ([`mod@crate::kernels::qconv`]).  Patch
/// row `r` decodes as `(bi, oi, oj)` of the `[B, H', W']` output grid;
/// ordering within a row is (di, dj, c), identical to [`im2col`].
pub fn im2col_rows_into(
    xd: &[f32],
    dims: (usize, usize, usize, usize),
    kh: usize,
    kw: usize,
    row0: usize,
    nrows: usize,
    dst: &mut [f32],
) {
    im2col_rows_t_into(xd, dims, kh, kw, row0, nrows, dst)
}

/// [`im2col_rows_into`] on raw i16 activations — the patch-staging primitive
/// of the integer datapath.  Structural copies only, so it is the same
/// function elementwise as the f32 form.
pub fn im2col_rows_i16_into(
    xd: &[i16],
    dims: (usize, usize, usize, usize),
    kh: usize,
    kw: usize,
    row0: usize,
    nrows: usize,
    dst: &mut [i16],
) {
    im2col_rows_t_into(xd, dims, kh, kw, row0, nrows, dst)
}

fn im2col_rows_t_into<T: Copy>(
    xd: &[T],
    (b, h, w, c): (usize, usize, usize, usize),
    kh: usize,
    kw: usize,
    row0: usize,
    nrows: usize,
    dst: &mut [T],
) {
    let (oh, ow) = (h - kh + 1, w - kw + 1);
    let kcols = kh * kw * c;
    debug_assert!(row0 + nrows <= b * oh * ow);
    debug_assert!(dst.len() >= nrows * kcols);
    for r in 0..nrows {
        let pr = row0 + r;
        let oj = pr % ow;
        let rest = pr / ow;
        let oi = rest % oh;
        let bi = rest / oh;
        let drow = r * kcols;
        for di in 0..kh {
            // one contiguous (kw*c)-long strip per kernel row
            let src = ((bi * h + oi + di) * w + oj) * c;
            let dcol = drow + di * kw * c;
            dst[dcol..dcol + kw * c].copy_from_slice(&xd[src..src + kw * c]);
        }
    }
}

/// VALID conv, NHWC x [B,H,W,C] * w [kh,kw,C,OC] -> [B,H',W',OC].
pub fn conv2d(x: &Tensor, w: &Tensor) -> Result<Tensor> {
    let ws = w.shape();
    if ws.len() != 4 {
        bail!("conv2d weight must be [kh,kw,C,OC], got {:?}", ws);
    }
    let (kh, kw, c, oc) = (ws[0], ws[1], ws[2], ws[3]);
    if x.shape()[3] != c {
        bail!("conv2d channel mismatch: x {:?} vs w {:?}", x.shape(), ws);
    }
    let (patches, oh, ow) = im2col(x, kh, kw)?;
    let wf = w.reshape(vec![kh * kw * c, oc])?;
    let out = matmul(&patches, &wf)?;
    out.reshape(vec![x.shape()[0], oh, ow, oc])
}

/// SAME conv (odd kernel): zero-pad then VALID.
pub fn conv2d_same(x: &Tensor, w: &Tensor) -> Result<Tensor> {
    let p = w.shape()[0] / 2;
    conv2d(&pad_hw(x, p)?, w)
}

/// Zero-pad H and W by `p` on each side.
pub fn pad_hw(x: &Tensor, p: usize) -> Result<Tensor> {
    let s = x.shape();
    if s.len() != 4 {
        bail!("pad_hw expects NHWC");
    }
    let (b, h, w, c) = (s[0], s[1], s[2], s[3]);
    let (nh, nw) = (h + 2 * p, w + 2 * p);
    let mut out = vec![0.0f32; b * nh * nw * c];
    pad_hw_into(x.data(), (b, h, w, c), p, &mut out);
    Tensor::new(vec![b, nh, nw, c], out)
}

/// Zero-pad H and W by `p` into `dst` (`b*(h+2p)*(w+2p)*c` floats, which the
/// caller has zeroed — only the interior strips are written).
pub fn pad_hw_into(
    xd: &[f32],
    dims: (usize, usize, usize, usize),
    p: usize,
    dst: &mut [f32],
) {
    pad_hw_t_into(xd, dims, p, dst)
}

/// [`pad_hw_into`] on raw i16 activations (caller zeroes `dst`; zero raw is
/// zero in every Q-format, so integer SAME-conv padding is exact).
pub fn pad_hw_i16_into(
    xd: &[i16],
    dims: (usize, usize, usize, usize),
    p: usize,
    dst: &mut [i16],
) {
    pad_hw_t_into(xd, dims, p, dst)
}

fn pad_hw_t_into<T: Copy>(
    xd: &[T],
    (b, h, w, c): (usize, usize, usize, usize),
    p: usize,
    dst: &mut [T],
) {
    let (nh, nw) = (h + 2 * p, w + 2 * p);
    debug_assert!(dst.len() >= b * nh * nw * c);
    for bi in 0..b {
        for hi in 0..h {
            let src = ((bi * h + hi) * w) * c;
            let d = ((bi * nh + hi + p) * nw + p) * c;
            dst[d..d + w * c].copy_from_slice(&xd[src..src + w * c]);
        }
    }
}

/// `buf` is `[rows, n]` row-major: add the bias vector then ReLU, in place —
/// the fused pipeline's layer epilogue (no intermediate tensors).
pub fn bias_relu_inplace(buf: &mut [f32], bias: &[f32]) {
    let n = bias.len();
    if n == 0 {
        return;
    }
    for row in buf.chunks_exact_mut(n) {
        for (v, &bv) in row.iter_mut().zip(bias) {
            *v = (*v + bv).max(0.0);
        }
    }
}

/// `buf` is `[rows, n]` row-major: add the bias vector in place (final
/// logits — no activation).
pub fn bias_inplace(buf: &mut [f32], bias: &[f32]) {
    let n = bias.len();
    if n == 0 {
        return;
    }
    for row in buf.chunks_exact_mut(n) {
        for (v, &bv) in row.iter_mut().zip(bias) {
            *v += bv;
        }
    }
}

/// 2x2/stride-2 max pool from `src` `[b,h,w,c]` (h, w even) into `dst`
/// `[b,h/2,w/2,c]` (fully overwritten).
pub fn maxpool2_into(src: &[f32], dims: (usize, usize, usize, usize), dst: &mut [f32]) {
    maxpool2_t_into(src, dims, dst, f32::max)
}

/// [`maxpool2_into`] on raw i16 activations.  Max commutes with the
/// (monotone) quantization map, so pooling raw values equals quantizing the
/// f32 pool output — the integer pipeline pools without dequantizing.
pub fn maxpool2_i16_into(src: &[i16], dims: (usize, usize, usize, usize), dst: &mut [i16]) {
    maxpool2_t_into(src, dims, dst, std::cmp::max)
}

fn maxpool2_t_into<T: Copy, M: Fn(T, T) -> T>(
    src: &[T],
    (b, h, w, c): (usize, usize, usize, usize),
    dst: &mut [T],
    max: M,
) {
    debug_assert!(h % 2 == 0 && w % 2 == 0);
    let (oh, ow) = (h / 2, w / 2);
    debug_assert!(dst.len() >= b * oh * ow * c);
    for bi in 0..b {
        for oi in 0..oh {
            for oj in 0..ow {
                let r0 = ((bi * h + 2 * oi) * w + 2 * oj) * c;
                let r1 = r0 + w * c;
                let o = ((bi * oh + oi) * ow + oj) * c;
                for ci in 0..c {
                    let m0 = max(src[r0 + ci], src[r0 + c + ci]);
                    let m1 = max(src[r1 + ci], src[r1 + c + ci]);
                    dst[o + ci] = max(m0, m1);
                }
            }
        }
    }
}

/// 2x2 max pool, stride 2 (H, W must be even).
pub fn maxpool2(x: &Tensor) -> Result<Tensor> {
    let s = x.shape();
    if s.len() != 4 || s[1] % 2 != 0 || s[2] % 2 != 0 {
        bail!("maxpool2 expects NHWC with even H,W, got {:?}", s);
    }
    let (b, h, w, c) = (s[0], s[1], s[2], s[3]);
    let (oh, ow) = (h / 2, w / 2);
    let mut out = vec![f32::NEG_INFINITY; b * oh * ow * c];
    for bi in 0..b {
        for hi in 0..h {
            for wi in 0..w {
                for ci in 0..c {
                    let v = x.at4(bi, hi, wi, ci);
                    let o = ((bi * oh + hi / 2) * ow + wi / 2) * c + ci;
                    if v > out[o] {
                        out[o] = v;
                    }
                }
            }
        }
    }
    Tensor::new(vec![b, oh, ow, c], out)
}

/// Row-wise argmax of a [M,N] tensor.
pub fn argmax_rows(x: &Tensor) -> Vec<usize> {
    let (m, n) = (x.shape()[0], x.shape()[1]);
    (0..m)
        .map(|i| {
            let row = &x.data()[i * n..(i + 1) * n];
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(j, _)| j)
                .unwrap_or(0)
        })
        .collect()
}

/// Row-wise softmax (numerically stabilized).
pub fn softmax_rows(x: &Tensor) -> Tensor {
    let (m, n) = (x.shape()[0], x.shape()[1]);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let row = &x.data()[i * n..(i + 1) * n];
        let mx = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut sum = 0.0;
        for j in 0..n {
            let e = (row[j] - mx).exp();
            out[i * n + j] = e;
            sum += e;
        }
        for j in 0..n {
            out[i * n + j] /= sum;
        }
    }
    Tensor::new(vec![m, n], out).unwrap()
}

/// Mean softmax cross-entropy given integer labels.
pub fn xent(logits: &Tensor, labels: &[usize]) -> f32 {
    let p = softmax_rows(logits);
    let n = logits.shape()[1];
    let mut tot = 0.0;
    for (i, &y) in labels.iter().enumerate() {
        tot -= p.data()[i * n + y].max(1e-12).ln();
    }
    tot / labels.len() as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(shape: &[usize], data: &[f32]) -> Tensor {
        Tensor::new(shape.to_vec(), data.to_vec()).unwrap()
    }

    #[test]
    fn matmul_small() {
        let x = t(&[2, 2], &[1.0, 2.0, 3.0, 4.0]);
        let w = t(&[2, 2], &[1.0, 1.0, 1.0, 1.0]);
        assert_eq!(matmul(&x, &w).unwrap().data(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_rejects_mismatch() {
        let x = t(&[2, 3], &[0.0; 6]);
        let w = t(&[2, 2], &[0.0; 4]);
        assert!(matmul(&x, &w).is_err());
        assert!(matmul_naive(&x, &w).is_err());
    }

    #[test]
    fn blocked_matmul_matches_naive_oracle() {
        let mut r = crate::util::rng::Rng::new(11);
        let xd: Vec<f32> = (0..19 * 77).map(|_| (r.normal()) as f32).collect();
        let wd: Vec<f32> = (0..77 * 130).map(|_| (r.normal()) as f32).collect();
        let x = t(&[19, 77], &xd);
        let w = t(&[77, 130], &wd);
        let fast = matmul(&x, &w).unwrap();
        let slow = matmul_naive(&x, &w).unwrap();
        assert_eq!(fast.data(), slow.data());
    }

    #[test]
    fn add_bias_broadcasts_rows() {
        let x = t(&[2, 3], &[0., 1., 2., 3., 4., 5.]);
        let b = t(&[3], &[10., 20., 30.]);
        assert_eq!(add_bias(&x, &b).unwrap().data(), &[10., 21., 32., 13., 24., 35.]);
        let bad = t(&[2], &[1., 2.]);
        assert!(add_bias(&x, &bad).is_err());
    }

    #[test]
    fn conv_identity_kernel() {
        // 1x1 kernel with weight 1.0 reproduces input
        let x = t(&[1, 3, 3, 1], &[1., 2., 3., 4., 5., 6., 7., 8., 9.]);
        let w = t(&[1, 1, 1, 1], &[1.0]);
        let y = conv2d(&x, &w).unwrap();
        assert_eq!(y.shape(), &[1, 3, 3, 1]);
        assert_eq!(y.data(), x.data());
    }

    #[test]
    fn conv_sum_kernel() {
        // 2x2 all-ones kernel = sliding-window sum
        let x = t(&[1, 3, 3, 1], &[1., 2., 3., 4., 5., 6., 7., 8., 9.]);
        let w = t(&[2, 2, 1, 1], &[1.0; 4]);
        let y = conv2d(&x, &w).unwrap();
        assert_eq!(y.shape(), &[1, 2, 2, 1]);
        assert_eq!(y.data(), &[12.0, 16.0, 24.0, 28.0]);
    }

    #[test]
    fn conv_same_preserves_hw() {
        let x = Tensor::zeros(vec![2, 8, 8, 3]);
        let w = Tensor::zeros(vec![3, 3, 3, 5]);
        let y = conv2d_same(&x, &w).unwrap();
        assert_eq!(y.shape(), &[2, 8, 8, 5]);
    }

    #[test]
    fn im2col_ordering_matches_python() {
        // x [1,2,2,2] with distinct values; kernel 2x2 -> single patch whose
        // ordering must be (di, dj, c): [x00c0,x00c1,x01c0,x01c1,x10c0,...]
        let x = t(&[1, 2, 2, 2], &[0., 1., 2., 3., 4., 5., 6., 7.]);
        let (p, oh, ow) = im2col(&x, 2, 2).unwrap();
        assert_eq!((oh, ow), (1, 1));
        assert_eq!(p.data(), &[0., 1., 2., 3., 4., 5., 6., 7.]);
    }

    #[test]
    fn maxpool_small() {
        let x = t(&[1, 2, 2, 1], &[1.0, 5.0, 3.0, 2.0]);
        assert_eq!(maxpool2(&x).unwrap().data(), &[5.0]);
    }

    #[test]
    fn maxpool_odd_rejected() {
        assert!(maxpool2(&Tensor::zeros(vec![1, 3, 4, 1])).is_err());
    }

    #[test]
    fn argmax_and_softmax() {
        let x = t(&[2, 3], &[0.1, 0.9, 0.0, 3.0, 1.0, 2.0]);
        assert_eq!(argmax_rows(&x), vec![1, 0]);
        let p = softmax_rows(&x);
        for i in 0..2 {
            let s: f32 = p.data()[i * 3..(i + 1) * 3].iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn xent_decreases_with_confidence() {
        let good = t(&[1, 3], &[10.0, 0.0, 0.0]);
        let bad = t(&[1, 3], &[0.0, 10.0, 0.0]);
        assert!(xent(&good, &[0]) < xent(&bad, &[0]));
    }

    #[test]
    fn im2col_rows_into_matches_full_matrix() {
        let mut r = crate::util::rng::Rng::new(3);
        let data: Vec<f32> = (0..2 * 6 * 5 * 3).map(|_| (r.normal()) as f32).collect();
        let x = t(&[2, 6, 5, 3], &data);
        let (full, oh, ow) = im2col(&x, 3, 2).unwrap();
        let kcols = 3 * 2 * 3;
        let rows = 2 * oh * ow;
        // every (row0, nrows) band must reproduce the matching slice
        for (row0, nrows) in [(0usize, rows), (3, 4), (rows - 2, 2), (5, 1)] {
            let mut band = vec![0.0f32; nrows * kcols];
            im2col_rows_into(x.data(), (2, 6, 5, 3), 3, 2, row0, nrows, &mut band);
            assert_eq!(
                &band[..],
                &full.data()[row0 * kcols..(row0 + nrows) * kcols],
                "band ({row0},{nrows})"
            );
        }
    }

    #[test]
    fn inplace_epilogues_match_tensor_ops() {
        let x = t(&[2, 3], &[0., -1., 2., 3., -4., 5.]);
        let b = t(&[3], &[0.5, 0.5, -10.]);
        let want_relu = add_bias(&x, &b).unwrap().relu();
        let mut buf = x.data().to_vec();
        bias_relu_inplace(&mut buf, b.data());
        assert_eq!(&buf[..], want_relu.data());
        let want_bias = add_bias(&x, &b).unwrap();
        let mut buf = x.data().to_vec();
        bias_inplace(&mut buf, b.data());
        assert_eq!(&buf[..], want_bias.data());
    }

    #[test]
    fn maxpool2_into_matches_maxpool2() {
        let mut r = crate::util::rng::Rng::new(4);
        let data: Vec<f32> = (0..2 * 4 * 6 * 3).map(|_| (r.normal()) as f32).collect();
        let x = t(&[2, 4, 6, 3], &data);
        let want = maxpool2(&x).unwrap();
        let mut dst = vec![0.0f32; 2 * 2 * 3 * 3];
        maxpool2_into(x.data(), (2, 4, 6, 3), &mut dst);
        assert_eq!(&dst[..], want.data());
    }

    #[test]
    fn i16_structural_ops_match_f32_forms_elementwise() {
        // Integer-valued data round-trips f32 exactly, so the i16 structural
        // ops (copy/pad/max only — no arithmetic) must mirror the f32 ones.
        let mut r = crate::util::rng::Rng::new(9);
        let dims = (2usize, 4usize, 6usize, 3usize);
        let n = 2 * 4 * 6 * 3;
        let qi: Vec<i16> = (0..n).map(|_| r.range_i64(-32768, 32767) as i16).collect();
        let xf: Vec<f32> = qi.iter().map(|&v| v as f32).collect();

        let kcols = 3 * 2 * 3;
        let rows = 2 * 2 * 5;
        let mut bf = vec![0.0f32; rows * kcols];
        let mut bq = vec![0i16; rows * kcols];
        im2col_rows_into(&xf, dims, 3, 2, 0, rows, &mut bf);
        im2col_rows_i16_into(&qi, dims, 3, 2, 0, rows, &mut bq);
        assert!(bf.iter().zip(&bq).all(|(&f, &q)| f == q as f32), "im2col diverged");

        let padded = 2 * 6 * 8 * 3;
        let mut pf = vec![0.0f32; padded];
        let mut pq = vec![0i16; padded];
        pad_hw_into(&xf, dims, 1, &mut pf);
        pad_hw_i16_into(&qi, dims, 1, &mut pq);
        assert!(pf.iter().zip(&pq).all(|(&f, &q)| f == q as f32), "pad diverged");

        let pooled = 2 * 2 * 3 * 3;
        let mut mf = vec![0.0f32; pooled];
        let mut mq = vec![0i16; pooled];
        maxpool2_into(&xf, dims, &mut mf);
        maxpool2_i16_into(&qi, dims, &mut mq);
        assert!(mf.iter().zip(&mq).all(|(&f, &q)| f == q as f32), "maxpool diverged");
    }

    #[test]
    fn pad_hw_places_center() {
        let x = t(&[1, 1, 1, 1], &[7.0]);
        let p = pad_hw(&x, 1).unwrap();
        assert_eq!(p.shape(), &[1, 3, 3, 1]);
        assert_eq!(p.at4(0, 1, 1, 0), 7.0);
        assert_eq!(p.data().iter().sum::<f32>(), 7.0);
    }
}
