//! Host-side f32 tensor substrate: shapes, NHWC conv (im2col, mirroring the
//! python kernel ordering), pooling, dense layers.  Powers the pure-rust
//! fallback inference engine ([`crate::runtime::host`]) and serves as the
//! oracle the PJRT path is validated against.

pub mod ops;
pub mod tensor;

pub use tensor::Tensor;
