//! Dense row-major (C-order) f32 tensor.

use anyhow::{bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} needs {} elements, got {}", shape, n, data.len());
        }
        Ok(Tensor { shape, data })
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Reinterpret with a new shape of equal element count.
    pub fn reshape(&self, shape: Vec<usize>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            bail!("reshape {:?} -> {:?}: element count mismatch", self.shape, shape);
        }
        Ok(Tensor { shape, data: self.data.clone() })
    }

    /// 2-D accessor (row-major).
    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.shape.len(), 2);
        self.data[i * self.shape[1] + j]
    }

    /// 4-D accessor (NHWC).
    #[inline]
    pub fn at4(&self, n: usize, h: usize, w: usize, c: usize) -> f32 {
        debug_assert_eq!(self.shape.len(), 4);
        let (_, sh, sw, sc) = (self.shape[0], self.shape[1], self.shape[2], self.shape[3]);
        self.data[((n * sh + h) * sw + w) * sc + c]
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor { shape: self.shape.clone(), data: self.data.iter().map(|&v| f(v)).collect() }
    }

    pub fn relu(&self) -> Tensor {
        self.map(|v| v.max(0.0))
    }

    /// Max absolute difference against another tensor of the same shape.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    }

    /// Frobenius norm.
    pub fn norm(&self) -> f32 {
        self.data.iter().map(|v| v * v).sum::<f32>().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_accessors() {
        let t = Tensor::new(vec![2, 3], (0..6).map(|v| v as f32).collect()).unwrap();
        assert_eq!(t.at2(1, 2), 5.0);
        assert_eq!(t.shape(), &[2, 3]);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::new(vec![2, 3], (0..6).map(|v| v as f32).collect()).unwrap();
        let r = t.reshape(vec![3, 2]).unwrap();
        assert_eq!(r.at2(2, 1), 5.0);
        assert!(t.reshape(vec![4, 2]).is_err());
    }

    #[test]
    fn at4_nhwc_layout() {
        let t = Tensor::new(vec![1, 2, 2, 3], (0..12).map(|v| v as f32).collect()).unwrap();
        assert_eq!(t.at4(0, 0, 0, 0), 0.0);
        assert_eq!(t.at4(0, 0, 1, 0), 3.0);
        assert_eq!(t.at4(0, 1, 0, 2), 8.0);
    }

    #[test]
    fn relu_and_norm() {
        let t = Tensor::new(vec![3], vec![-1.0, 0.0, 2.0]).unwrap();
        assert_eq!(t.relu().data(), &[0.0, 0.0, 2.0]);
        assert!((t.norm() - 5f32.sqrt()).abs() < 1e-6);
    }
}
