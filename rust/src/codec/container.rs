//! The `QSQ1` model container — the byte format an encoded model travels in.
//!
//! ```text
//! [magic "QSQ1"][u16 version][u8 n_tensors]
//! per tensor:
//!   [u8 name_len][name][u8 rank][u32 dims...]
//!   [u8 phi][u8 bits][u32 group][f32 gamma][f32 delta]
//!   [u32 n_scalars][f32 scalars...]
//!   [u32 n_codes][packed codes (bits/code)]
//!   [u32 crc32 of this tensor section]
//! [u32 crc32 of everything before]
//! ```
//!
//! Little-endian throughout.  Every tensor section carries its own CRC so a
//! receiver behind a lossy link can pinpoint corruption (see
//! [`crate::channel`]) and request selective retransmission.
//!
//! [`decode_model`] is the hot-swap path's first line of defense, so it is
//! hardened against hostile bytes: each section is first walked by a
//! bounds-only scan that validates every length field against the bytes
//! actually present, then its CRC is checked (a mismatch names the offending
//! tensor), and only a CRC-verified slice reaches the allocating parse.
//! Truncated, bit-flipped, or garbage input yields an error — never a panic
//! or an attacker-sized allocation (see `tests/test_codec_fuzz.rs`).

use anyhow::{bail, Context, Result};

use super::crc::crc32;
use super::pack::{pack_codes, packed_len, unpack_codes};
use crate::quant::codes::{code_bits, Code};
use crate::quant::QuantizedTensor;

pub const MAGIC: &[u8; 4] = b"QSQ1";
pub const VERSION: u16 = 1;

/// Wire remap for phi=1 (2-bit) streams: the ternary alphabet {0, +1, -1}
/// uses Table-II codes {0, 1, 4}; on the wire they compact to {0, 1, 2}.
fn to_wire(c: Code, phi: u32) -> Result<u8> {
    if phi == 1 {
        Ok(match c.0 {
            0 | 7 => 0,
            1 => 1,
            4 => 2,
            other => bail!("code {other} invalid for phi=1"),
        })
    } else {
        Ok(c.0)
    }
}

fn from_wire(w: u8, phi: u32) -> Result<Code> {
    if phi == 1 {
        Ok(match w {
            0 => Code(0),
            1 => Code(1),
            2 => Code(4),
            other => bail!("wire code {other} invalid for phi=1"),
        })
    } else {
        Ok(Code(w))
    }
}

/// One encoded tensor section (decoded form).
#[derive(Clone, Debug)]
pub struct EncodedTensor {
    pub name: String,
    pub tensor: QuantizedTensor,
}

/// A whole encoded model.
#[derive(Clone, Debug)]
pub struct EncodedModel {
    pub tensors: Vec<EncodedTensor>,
}

impl EncodedModel {
    /// Total payload bits (eq.-12 accounting, as actually serialized).
    pub fn encoded_bits(&self) -> u64 {
        self.tensors.iter().map(|t| t.tensor.encoded_bits(32)).sum()
    }

    pub fn full_precision_bits(&self) -> u64 {
        self.tensors.iter().map(|t| t.tensor.full_precision_bits(32)).sum()
    }

    pub fn get(&self, name: &str) -> Option<&QuantizedTensor> {
        self.tensors.iter().find(|t| t.name == name).map(|t| &t.tensor)
    }
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Serialize a model to container bytes.
pub fn encode_model(model: &EncodedModel) -> Result<Vec<u8>> {
    if model.tensors.len() > 255 {
        bail!("too many tensors");
    }
    let mut out = Vec::new();
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.push(model.tensors.len() as u8);

    for et in &model.tensors {
        let t = &et.tensor;
        let mut sec = Vec::new();
        let name = et.name.as_bytes();
        if name.len() > 255 {
            bail!("tensor name too long");
        }
        sec.push(name.len() as u8);
        sec.extend_from_slice(name);
        sec.push(t.shape.len() as u8);
        for &d in &t.shape {
            put_u32(&mut sec, d as u32);
        }
        let bits = code_bits(t.phi);
        sec.push(t.phi as u8);
        sec.push(bits as u8);
        put_u32(&mut sec, t.group as u32);
        put_f32(&mut sec, t.gamma as f32);
        put_f32(&mut sec, t.delta as f32);
        put_u32(&mut sec, t.scalars.len() as u32);
        for &s in &t.scalars {
            put_f32(&mut sec, s);
        }
        put_u32(&mut sec, t.codes.len() as u32);
        let wire_codes: Vec<Code> = t
            .codes
            .iter()
            .map(|&c| to_wire(c, t.phi).map(Code))
            .collect::<Result<_>>()?;
        sec.extend_from_slice(&pack_codes(&wire_codes, bits)?);
        let c = crc32(&sec);
        out.extend_from_slice(&sec);
        put_u32(&mut out, c);
    }
    let total = crc32(&out);
    put_u32(&mut out, total);
    Ok(out)
}

struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            bail!("container truncated at byte {}", self.i);
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
}

/// Bounds-only walk of one tensor section starting at `c.i`: every length
/// field is validated against the bytes actually present *before* anything
/// is allocated, and the walk returns the raw name bytes for diagnostics.
/// Running this scan (then the section CRC check) ahead of the real parse is
/// what makes [`decode_model`] panic-free on arbitrary garbage — a corrupt
/// scalar count of four billion must yield an error, not an allocation the
/// size of the lie.
fn scan_section<'a>(c: &mut Cursor<'a>) -> Result<&'a [u8]> {
    let name_len = c.u8()? as usize;
    let name = c.take(name_len)?;
    let rank = c.u8()? as usize;
    c.take(4 * rank)?; // dims
    let _phi = c.u8()?;
    let bits = c.u8()? as u32;
    c.take(12)?; // group, gamma, delta
    let n_scalars = c.u32()? as usize;
    c.take(n_scalars.checked_mul(4).context("scalar count overflows")?)?;
    let n_codes = c.u32()? as usize;
    // a packed code costs at least one wire bit, so any count beyond 8x the
    // remaining bytes is corrupt; bounding it here also keeps the
    // packed-length arithmetic overflow-free on 32-bit targets
    if n_codes > c.b.len().saturating_sub(c.i).saturating_mul(8) {
        bail!("code count {n_codes} exceeds the container");
    }
    c.take(packed_len(n_codes, bits))?;
    Ok(name)
}

/// Parse container bytes back into a model, verifying all CRCs.
pub fn decode_model(bytes: &[u8]) -> Result<EncodedModel> {
    if bytes.len() < 11 {
        bail!("container too short");
    }
    let (body, tail) = bytes.split_at(bytes.len() - 4);
    let total_crc = u32::from_le_bytes(tail.try_into().unwrap());
    // deferred to the end so a section-level CRC failure can name the
    // offending tensor instead of drowning in the whole-container mismatch
    let total_ok = crc32(body) == total_crc;
    let mut c = Cursor { b: body, i: 0 };
    if c.take(4)? != MAGIC {
        bail!("bad magic");
    }
    let ver = c.u16()?;
    if ver != VERSION {
        bail!("unsupported container version {ver}");
    }
    let n_tensors = c.u8()? as usize;
    let mut tensors = Vec::with_capacity(n_tensors);
    for sec_idx in 0..n_tensors {
        let sec_start = c.i;
        // phase 1: bounds-only scan establishes the section's extent (and a
        // best-effort name) without trusting a single length field
        let mut scan = Cursor { b: body, i: sec_start };
        let raw_name =
            scan_section(&mut scan).with_context(|| format!("tensor section {sec_idx}"))?;
        let sec_end = scan.i;
        let stored = scan.u32().with_context(|| format!("tensor section {sec_idx}"))?;
        // phase 2: the section CRC gates the allocating parse
        if crc32(&body[sec_start..sec_end]) != stored {
            bail!(
                "tensor section {sec_idx} ({}): section CRC mismatch",
                String::from_utf8_lossy(raw_name)
            );
        }
        // phase 3: strict parse of the CRC-verified slice — every allocation
        // below is bounded by the scan above
        let name_len = c.u8()? as usize;
        let name = String::from_utf8(c.take(name_len)?.to_vec()).context("tensor name")?;
        let rank = c.u8()? as usize;
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(c.u32()? as usize);
        }
        let phi = c.u8()? as u32;
        let bits = c.u8()? as u32;
        if !matches!(phi, 1 | 2 | 4) || bits != code_bits(phi) {
            bail!("tensor {name}: inconsistent phi={phi}/bits={bits}");
        }
        let group = c.u32()? as usize;
        let gamma = c.f32()? as f64;
        let delta = c.f32()? as f64;
        let n_scalars = c.u32()? as usize;
        let mut scalars = Vec::with_capacity(n_scalars);
        for _ in 0..n_scalars {
            scalars.push(c.f32()?);
        }
        let n_codes = c.u32()? as usize;
        let packed = c.take(packed_len(n_codes, bits))?;
        let codes = unpack_codes(packed, n_codes, bits)?
            .into_iter()
            .map(|w| from_wire(w.0, phi))
            .collect::<Result<Vec<Code>>>()?;
        c.u32()?; // section CRC — already verified in phase 2
        let (k, oc) = crate::quant::qsq::matrix_dims(&shape)?;
        if k * oc != n_codes || group == 0 || k % group != 0 || (k / group) * oc != n_scalars {
            bail!("tensor {name}: inconsistent geometry");
        }
        tensors.push(EncodedTensor {
            name,
            tensor: QuantizedTensor {
                codes,
                scalars,
                k,
                oc,
                group,
                phi,
                gamma,
                delta,
                shape,
            },
        });
    }
    if c.i != body.len() {
        bail!("trailing bytes in container");
    }
    if !total_ok {
        bail!("container total CRC mismatch");
    }
    Ok(EncodedModel { tensors })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::qsq::{quantize, AssignMode};
    use crate::util::prop::gen_weights;
    use crate::util::rng::Rng;

    fn sample_model(seed: u64) -> EncodedModel {
        let mut r = Rng::new(seed);
        let w1 = gen_weights(&mut r, 150 * 16, 0.1);
        let w2 = gen_weights(&mut r, 64 * 10, 0.2);
        EncodedModel {
            tensors: vec![
                EncodedTensor {
                    name: "c2w".into(),
                    tensor: quantize(&w1, &[5, 5, 6, 16], 6, 4, AssignMode::SigmaSearch).unwrap(),
                },
                EncodedTensor {
                    name: "fc".into(),
                    tensor: quantize(&w2, &[64, 10], 16, 1, AssignMode::Nearest).unwrap(),
                },
            ],
        }
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let m = sample_model(1);
        let bytes = encode_model(&m).unwrap();
        let back = decode_model(&bytes).unwrap();
        assert_eq!(back.tensors.len(), 2);
        for (a, b) in m.tensors.iter().zip(&back.tensors) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.tensor.codes, b.tensor.codes);
            assert_eq!(a.tensor.scalars, b.tensor.scalars);
            assert_eq!(a.tensor.shape, b.tensor.shape);
            assert_eq!(a.tensor.group, b.tensor.group);
            assert_eq!(a.tensor.phi, b.tensor.phi);
        }
    }

    #[test]
    fn decoded_weights_identical_after_transit() {
        let m = sample_model(2);
        let back = decode_model(&encode_model(&m).unwrap()).unwrap();
        for (a, b) in m.tensors.iter().zip(&back.tensors) {
            assert_eq!(a.tensor.decode(), b.tensor.decode());
        }
    }

    #[test]
    fn container_smaller_than_full_precision() {
        let m = sample_model(3);
        let bytes = encode_model(&m).unwrap();
        let full_bytes = m.full_precision_bits() / 8;
        assert!(
            (bytes.len() as u64) < full_bytes / 3,
            "container {} vs full {}",
            bytes.len(),
            full_bytes
        );
    }

    #[test]
    fn corruption_detected() {
        let m = sample_model(4);
        let bytes = encode_model(&m).unwrap();
        // flip one bit anywhere -> total or section CRC must catch it
        for pos in [8usize, bytes.len() / 2, bytes.len() - 5] {
            let mut bad = bytes.clone();
            bad[pos] ^= 0x10;
            assert!(decode_model(&bad).is_err(), "corruption at {pos} undetected");
        }
    }

    #[test]
    fn truncation_detected() {
        let m = sample_model(5);
        let bytes = encode_model(&m).unwrap();
        assert!(decode_model(&bytes[..bytes.len() - 10]).is_err());
        assert!(decode_model(&[]).is_err());
    }

    #[test]
    fn section_crc_failure_names_the_tensor() {
        let m = sample_model(7);
        let bytes = encode_model(&m).unwrap();
        // flip a bit inside the first section's payload (header is 7 bytes,
        // the name sits at 8..11, the scalar/code payload starts after 42)
        let mut bad = bytes.clone();
        bad[40] ^= 0x04;
        let msg = format!("{:#}", decode_model(&bad).unwrap_err());
        assert!(msg.contains("section CRC mismatch"), "got: {msg}");
        assert!(msg.contains("c2w"), "error must name the tensor, got: {msg}");
    }

    #[test]
    fn hostile_scalar_count_errors_before_allocating() {
        // hand-build a section lying about n_scalars with valid CRCs: the
        // bounds scan must reject it without attempting the 16 GiB
        // allocation the lie implies
        let mut body = Vec::new();
        body.extend_from_slice(MAGIC);
        body.extend_from_slice(&VERSION.to_le_bytes());
        body.push(1); // one tensor
        let mut sec = Vec::new();
        sec.push(1); // name_len
        sec.push(b'x');
        sec.push(2); // rank
        put_u32(&mut sec, 4);
        put_u32(&mut sec, 4);
        sec.push(4); // phi
        sec.push(3); // bits
        put_u32(&mut sec, 4); // group
        put_f32(&mut sec, 1.0); // gamma
        put_f32(&mut sec, 0.5); // delta
        put_u32(&mut sec, u32::MAX); // n_scalars: the lie
        let sc = crc32(&sec);
        body.extend_from_slice(&sec);
        put_u32(&mut body, sc);
        let total = crc32(&body);
        put_u32(&mut body, total);
        let msg = format!("{:#}", decode_model(&body).unwrap_err());
        assert!(msg.contains("tensor section 0"), "got: {msg}");
    }

    #[test]
    fn phi1_uses_2bit_packing() {
        let mut r = Rng::new(6);
        let w = gen_weights(&mut r, 1024, 0.1);
        let m = EncodedModel {
            tensors: vec![EncodedTensor {
                name: "t".into(),
                tensor: quantize(&w, &[1024, 1], 16, 1, AssignMode::Nearest).unwrap(),
            }],
        };
        let bytes = encode_model(&m).unwrap();
        // 1024 codes * 2 bits = 256 bytes of codes; well under 3-bit packing
        assert!(bytes.len() < 256 + 64 * 4 + 64);
    }
}
