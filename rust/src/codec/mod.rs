//! Model container codec: how an encoded DNN travels over the channel.
//!
//! * [`pack`]      — dense 2-/3-bit code bitstreams (LSB-first).
//! * [`crc`]       — CRC-32 (IEEE) integrity check.
//! * [`container`] — the `QSQ1` binary container: header + per-tensor
//!   sections (codes, scalars, metadata), each CRC-protected, suitable for
//!   framing over the simulated link and decode at the edge.

pub mod container;
pub mod crc;
pub mod pack;

pub use container::{decode_model, encode_model, EncodedModel, EncodedTensor};
