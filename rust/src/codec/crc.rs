//! CRC-32 (IEEE 802.3, reflected polynomial 0xEDB88320), table-driven.

const POLY: u32 = 0xEDB8_8320;

fn table() -> &'static [u32; 256] {
    use std::sync::OnceLock;
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, slot) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { (c >> 1) ^ POLY } else { c >> 1 };
            }
            *slot = c;
        }
        t
    })
}

/// CRC-32 of a byte slice.
pub fn crc32(data: &[u8]) -> u32 {
    let t = table();
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = t[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, forall};
    use crate::util::rng::Rng;

    #[test]
    fn known_vectors() {
        // standard check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"a"), 0xE8B7_BE43);
    }

    #[test]
    fn prop_detects_single_bit_flip() {
        forall(
            100,
            |r: &mut Rng| {
                let n = r.below(64) as usize + 1;
                let data: Vec<u8> = (0..n).map(|_| r.below(256) as u8).collect();
                let pos = r.below(n as u64 * 8);
                (data, pos)
            },
            |(data, pos)| {
                let orig = crc32(data);
                let mut flipped = data.clone();
                flipped[(pos / 8) as usize] ^= 1 << (pos % 8);
                check(crc32(&flipped) != orig, "bit flip undetected")
            },
        );
    }
}
