//! Dense bit packing for 2-/3-bit code streams (LSB-first within bytes).
//!
//! This is where the paper's memory-savings claim becomes real bytes: a
//! 3-bit code stream occupies ceil(3n/8) bytes on the wire, not n bytes.

use anyhow::{bail, Result};

use crate::quant::codes::Code;

/// Append `bits` low bits of `value` to the stream.
#[derive(Default)]
pub struct BitWriter {
    buf: Vec<u8>,
    bitpos: usize,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn put(&mut self, value: u32, bits: u32) {
        for i in 0..bits {
            let bit = (value >> i) & 1;
            let byte = self.bitpos / 8;
            if byte == self.buf.len() {
                self.buf.push(0);
            }
            self.buf[byte] |= (bit as u8) << (self.bitpos % 8);
            self.bitpos += 1;
        }
    }

    pub fn bit_len(&self) -> usize {
        self.bitpos
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }
}

/// LSB-first bit reader.
pub struct BitReader<'a> {
    buf: &'a [u8],
    bitpos: usize,
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        BitReader { buf, bitpos: 0 }
    }

    pub fn get(&mut self, bits: u32) -> Result<u32> {
        let mut out = 0u32;
        for i in 0..bits {
            let byte = self.bitpos / 8;
            if byte >= self.buf.len() {
                bail!("bit stream exhausted at bit {}", self.bitpos);
            }
            let bit = (self.buf[byte] >> (self.bitpos % 8)) & 1;
            out |= (bit as u32) << i;
            self.bitpos += 1;
        }
        Ok(out)
    }
}

/// Pack codes at `bits` per code (2 for phi=1, 3 for phi in {2,4}).
pub fn pack_codes(codes: &[Code], bits: u32) -> Result<Vec<u8>> {
    if !(1..=8).contains(&bits) {
        bail!("bits per code must be 1..=8");
    }
    let mut w = BitWriter::new();
    for c in codes {
        if (c.0 as u32) >= (1 << bits) {
            bail!("code {} does not fit in {bits} bits", c.0);
        }
        w.put(c.0 as u32, bits);
    }
    Ok(w.into_bytes())
}

/// Unpack `n` codes at `bits` per code.
pub fn unpack_codes(buf: &[u8], n: usize, bits: u32) -> Result<Vec<Code>> {
    let mut r = BitReader::new(buf);
    (0..n).map(|_| r.get(bits).map(|v| Code(v as u8))).collect()
}

/// Bytes needed for n codes at `bits` per code.
pub fn packed_len(n: usize, bits: u32) -> usize {
    (n * bits as usize + 7) / 8
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, forall};
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_3bit() {
        let codes: Vec<Code> = (0..17).map(|i| Code(i % 7)).collect();
        let packed = pack_codes(&codes, 3).unwrap();
        assert_eq!(packed.len(), packed_len(17, 3));
        assert_eq!(packed.len(), 7); // ceil(51/8)
        let back = unpack_codes(&packed, 17, 3).unwrap();
        assert_eq!(back, codes);
    }

    #[test]
    fn roundtrip_2bit() {
        let codes: Vec<Code> = vec![Code(0), Code(1), Code(2), Code(3), Code(1)];
        let packed = pack_codes(&codes, 2).unwrap();
        assert_eq!(packed.len(), 2);
        assert_eq!(unpack_codes(&packed, 5, 2).unwrap(), codes);
    }

    #[test]
    fn code_too_large_rejected() {
        assert!(pack_codes(&[Code(4)], 2).is_err());
        assert!(pack_codes(&[Code(4)], 3).is_ok());
    }

    #[test]
    fn truncated_stream_rejected() {
        let packed = pack_codes(&[Code(1); 10], 3).unwrap();
        assert!(unpack_codes(&packed[..1], 10, 3).is_err());
    }

    #[test]
    fn prop_roundtrip_random() {
        forall(
            100,
            |r: &mut Rng| {
                let bits = [2u32, 3, 4][r.below(3) as usize];
                let n = r.below(200) as usize;
                let codes: Vec<Code> =
                    (0..n).map(|_| Code(r.below(1 << bits) as u8)).collect();
                (codes, bits)
            },
            |(codes, bits)| {
                let packed = pack_codes(codes, *bits).map_err(|e| e.to_string())?;
                check(packed.len() == packed_len(codes.len(), *bits), "len")?;
                let back = unpack_codes(&packed, codes.len(), *bits).map_err(|e| e.to_string())?;
                check(&back == codes, "roundtrip")
            },
        );
    }

    #[test]
    fn density_beats_byte_per_code() {
        // the actual memory-savings mechanism: 3 bits/code on the wire
        assert!(packed_len(2400, 3) * 8 <= 2400 * 3 + 7);
        assert!(packed_len(2400, 3) < 2400);
    }
}
