//! Differential kernel-equivalence harness for the lane-ized plane sums
//! (`kernels::lanes`) — the gate for every SWAR/chunked fast path:
//!
//! (a) `gather_sum` vs the retained scalar oracle at every chunk/tail
//!     boundary length — bitwise where the scalar order is preserved
//!     (planes shorter than one chunk; integer-valued activations, where
//!     every addition is exact), ULP-bounded against an f64 reference on
//!     gaussian activations (lane folding reassociates, it must not lose
//!     accuracy);
//! (b) `sum_i8` / `sum_i16` bitwise vs their scalar oracles at the same
//!     boundary lengths, plus *overflow-adversarial* all-extremal inputs
//!     longer than one widening interval — a missed i16→i32-scale widen
//!     (or a sum past `i32::MAX`) fails loudly here instead of wrapping
//!     silently in a kernel;
//! (c) kernel-level differential: the lane-ized `qgemm2` / `csd_gemm`
//!     entry points vs their `*_scalar_on` twins on the same packed
//!     tensors, under a serial and a wide pool — bitwise on integer
//!     activations, tolerance + identical argmax on gaussian.

use qsq_edge::device::CsdQuality;
use qsq_edge::kernels::lanes::{
    gather_sum, gather_sum_scalar, sum_i16, sum_i16_scalar, sum_i8, sum_i8_scalar, F32_LANES,
    I16_LANES, I16_WIDEN_WORDS, I8_LANES, I8_WIDEN_WORDS,
};
use qsq_edge::kernels::{
    csd_gemm_into_on, csd_gemm_scalar_on, qgemm2_into_on, qgemm2_scalar_on, PackedCsdTensor,
    PackedQTensorV2, Pool,
};
use qsq_edge::quant::qsq::{quantize, AssignMode};
use qsq_edge::util::prop::{check, forall, gen_weights};
use qsq_edge::util::rng::Rng;

/// Every length that straddles a chunk or tail boundary of the `lane`-wide
/// fast path: empty, sub-chunk, the chunk edge itself, and the same edges
/// eight chunks in.
fn boundary_lengths(lane: usize) -> Vec<usize> {
    vec![
        0,
        1,
        lane - 1,
        lane,
        lane + 1,
        2 * lane - 1,
        2 * lane,
        8 * lane - 1,
        8 * lane,
        8 * lane + 1,
    ]
}

// --- (a) f32 gather lanes ----------------------------------------------------

#[test]
fn prop_gather_sum_bitwise_scalar_where_order_is_preserved() {
    forall(
        20,
        |r| r.next_u64(),
        |&seed| {
            let mut r = Rng::new(seed);
            // gaussian activations; planes shorter than one chunk take the
            // scalar loop verbatim, so equality is bitwise even here
            let xs = gen_weights(&mut r, 512, 1.0);
            for len in 0..F32_LANES {
                let offsets: Vec<u16> = (0..len).map(|_| r.below(512) as u16).collect();
                let (s, l) = (gather_sum_scalar(&offsets, &xs), gather_sum(&offsets, &xs));
                check(
                    s.to_bits() == l.to_bits(),
                    &format!("short plane len={len} must be bitwise scalar (seed {seed})"),
                )?;
            }
            // integer-valued activations: every addition is exact in f32,
            // so lane reassociation cannot change the value at any length
            let ints: Vec<f32> = (0..512).map(|_| r.range_i64(-16, 16) as f32).collect();
            for len in boundary_lengths(F32_LANES) {
                let offsets: Vec<u16> = (0..len).map(|_| r.below(512) as u16).collect();
                check(
                    gather_sum(&offsets, &ints) == gather_sum_scalar(&offsets, &ints),
                    &format!("integer plane len={len} diverged (seed {seed})"),
                )?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_gather_sum_ulp_bounded_on_gaussian_planes() {
    forall(
        20,
        |r| r.next_u64(),
        |&seed| {
            let mut r = Rng::new(seed);
            let xs = gen_weights(&mut r, 600, 1.0);
            for len in boundary_lengths(F32_LANES) {
                let offsets: Vec<u16> = (0..len).map(|_| r.below(600) as u16).collect();
                // both orders must sit within a summation-error bound of
                // the f64 reference; the bound scales with sum |x| and n
                let exact: f64 = offsets.iter().map(|&o| xs[o as usize] as f64).sum();
                let abs: f64 = offsets.iter().map(|&o| xs[o as usize].abs() as f64).sum();
                let bound = (len.max(1) as f64) * (f32::EPSILON as f64) * abs + 1e-12;
                let lane = gather_sum(&offsets, &xs) as f64;
                let scalar = gather_sum_scalar(&offsets, &xs) as f64;
                check(
                    (lane - exact).abs() <= bound,
                    &format!("lane sum off by {} > {bound} at len={len}", (lane - exact).abs()),
                )?;
                check(
                    (lane - scalar).abs() <= 2.0 * bound,
                    &format!("lane vs scalar gap {} at len={len}", (lane - scalar).abs()),
                )?;
            }
            Ok(())
        },
    );
}

// --- (b) SWAR word sums ------------------------------------------------------

#[test]
fn prop_swar_sums_bitwise_equal_scalar_at_every_boundary() {
    forall(
        20,
        |r| r.next_u64(),
        |&seed| {
            let mut r = Rng::new(seed);
            let i8s: Vec<i8> = (0..8 * I8_LANES + 1)
                .map(|_| r.range_i64(i8::MIN as i64, i8::MAX as i64) as i8)
                .collect();
            for len in boundary_lengths(I8_LANES) {
                check(
                    sum_i8(&i8s[..len]) == sum_i8_scalar(&i8s[..len]),
                    &format!("sum_i8 len={len} diverged (seed {seed})"),
                )?;
            }
            let i16s: Vec<i16> = (0..8 * I16_LANES + 1)
                .map(|_| r.range_i64(i16::MIN as i64, i16::MAX as i64) as i16)
                .collect();
            for len in boundary_lengths(I16_LANES) {
                check(
                    sum_i16(&i16s[..len]) == sum_i16_scalar(&i16s[..len]),
                    &format!("sum_i16 len={len} diverged (seed {seed})"),
                )?;
            }
            Ok(())
        },
    );
}

#[test]
fn swar_widening_survives_adversarial_extremes_past_the_interval() {
    // longer than one widening interval of all-extremal values: if the
    // implementation missed a widen, a u16 lane wraps at 257 words of i8
    // extremes and the total comes back wrong.  Straddle the interval
    // boundary itself (±1 word) and an interval-plus-tail length.
    for words in [I8_WIDEN_WORDS - 1, I8_WIDEN_WORDS, I8_WIDEN_WORDS + 1, 2 * I8_WIDEN_WORDS + 3] {
        for v in [i8::MIN, i8::MAX] {
            let n = words * I8_LANES + 5; // off-word tail too
            let xs = vec![v; n];
            assert_eq!(
                sum_i8(&xs),
                v as i64 * n as i64,
                "i8 extremes wrapped at {words} words of {v}"
            );
        }
        // alternating extremes: lanes see the worst-case biased magnitude
        // while the true sum stays near zero
        let n = words * I8_LANES;
        let xs: Vec<i8> = (0..n).map(|i| if i % 2 == 0 { i8::MIN } else { i8::MAX }).collect();
        assert_eq!(sum_i8(&xs), sum_i8_scalar(&xs), "alternating i8 extremes at {words} words");
    }
    // i16: one widening interval of extremes sums far past i32 range — a
    // premature i32 narrowing (the widening boundary the issue pins) or a
    // missed widen both fail here
    for words in [I16_WIDEN_WORDS - 1, I16_WIDEN_WORDS, I16_WIDEN_WORDS + 1] {
        for v in [i16::MIN, i16::MAX] {
            let n = words * I16_LANES + 3;
            let xs = vec![v; n];
            let want = v as i64 * n as i64;
            assert!(
                want.unsigned_abs() > i32::MAX as u64,
                "case must exceed i32 to be adversarial"
            );
            assert_eq!(sum_i16(&xs), want, "i16 extremes wrapped at {words} words of {v}");
        }
    }
}

// --- (c) kernel-level lane-vs-scalar differential ----------------------------

#[test]
fn qgemm2_lane_and_scalar_paths_agree_under_both_pool_widths() {
    let mut r = Rng::new(0x1A5E);
    // a shape whose per-cell planes straddle the chunk width both ways
    let (k, oc, group, m) = (96usize, 14usize, 16usize, 9usize);
    let w = gen_weights(&mut r, k * oc, 0.3);
    let qt = quantize(&w, &[k, oc], group, 4, AssignMode::SigmaSearch).unwrap();
    let p = PackedQTensorV2::pack(&qt).unwrap();
    for width in [1usize, 4] {
        let pool = Pool::new(width);
        // integer activations: plane sums are exact, lane == scalar bitwise
        let ints: Vec<f32> = (0..m * k).map(|_| r.range_i64(-8, 8) as f32).collect();
        let mut lane = vec![0.0f32; m * oc];
        let mut scalar = vec![0.0f32; m * oc];
        qgemm2_into_on(&pool, &mut lane, &ints, m, &p);
        qgemm2_scalar_on(&pool, &mut scalar, &ints, m, &p);
        assert_eq!(lane, scalar, "qgemm2 integer inputs must be bitwise (width {width})");
        // gaussian activations: ULP-scale agreement
        let xs = gen_weights(&mut r, m * k, 1.0);
        lane.fill(0.0);
        scalar.fill(0.0);
        qgemm2_into_on(&pool, &mut lane, &xs, m, &p);
        qgemm2_scalar_on(&pool, &mut scalar, &xs, m, &p);
        for (i, (l, s)) in lane.iter().zip(&scalar).enumerate() {
            assert!(
                (l - s).abs() < 1e-4,
                "qgemm2 cell {i} lane {l} vs scalar {s} (width {width})"
            );
        }
    }
}

#[test]
fn csd_lane_and_scalar_paths_agree_under_both_pool_widths() {
    let mut r = Rng::new(0xC5D);
    let (k, oc, m) = (80usize, 11usize, 7usize);
    let w = gen_weights(&mut r, k * oc, 0.25);
    let p = PackedCsdTensor::pack(&w, &[k, oc], CsdQuality::new(3)).unwrap();
    for width in [1usize, 4] {
        let pool = Pool::new(width);
        // ternary activations: digit-plane sums are exact either way
        let terns: Vec<f32> = (0..m * k).map(|_| r.range_i64(-1, 1) as f32).collect();
        let mut lane = vec![0.0f32; m * oc];
        let mut scalar = vec![0.0f32; m * oc];
        csd_gemm_into_on(&pool, &mut lane, &terns, m, &p);
        csd_gemm_scalar_on(&pool, &mut scalar, &terns, m, &p);
        assert_eq!(lane, scalar, "csd ternary inputs must be bitwise (width {width})");
        let xs = gen_weights(&mut r, m * k, 1.0);
        lane.fill(0.0);
        scalar.fill(0.0);
        csd_gemm_into_on(&pool, &mut lane, &xs, m, &p);
        csd_gemm_scalar_on(&pool, &mut scalar, &xs, m, &p);
        for (i, (l, s)) in lane.iter().zip(&scalar).enumerate() {
            assert!((l - s).abs() < 1e-4, "csd cell {i} lane {l} vs scalar {s} (width {width})");
        }
    }
}
