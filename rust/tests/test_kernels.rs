//! Property tests for the kernels layer (no artifacts needed):
//!
//! (a) code-domain `qgemm` (v1) and `qgemm2` (plane-packed v2) equal the
//!     decode-then-fp32-matmul oracle — exactly on dyadic data (where all
//!     paths are exact in f32, so v1 and v2 are bitwise equal), and within
//!     tight tolerance on real quantized gaussian tensors;
//! (b) the row-parallel v2 kernel is bitwise identical to its single-thread
//!     reference across band-boundary shapes (m < bands, m % bands != 0);
//! (c) the fused `qconv` equals the materialized pad + im2col + qgemm2
//!     oracle bitwise at LeNet and ConvNet layer shapes, VALID and SAME;
//! (d) the blocked/microtiled matmul equals the naive ikj loop within 1e-5
//!     (it is in fact bitwise identical — same reduction order);
//! (e) the O(sort) sigma-search picks the identical (gamma, delta, codes)
//!     as the naive 152-pass grid, including at ConvNet layer sizes.

use qsq_edge::kernels::{
    qconv, qgemm2, qgemm2_qt, qgemm2_threads, qgemm_qt, PackedQTensor, PackedQTensorV2, Scratch,
};
use qsq_edge::quant::codes::Code;
use qsq_edge::quant::qsq::{quantize, quantize_sigma_search_naive, AssignMode, QuantizedTensor};
use qsq_edge::quant::vectorize::Grouping;
use qsq_edge::tensor::{ops, Tensor};
use qsq_edge::util::prop::{check, forall, gen_weights};
use qsq_edge::util::rng::Rng;

/// Random codes + power-of-two scalars + integer activations: every
/// intermediate of both GEMMs is exactly representable in f32.
fn dyadic_case(seed: u64, m: usize, k: usize, oc: usize, group: usize) -> (Tensor, QuantizedTensor) {
    let mut r = Rng::new(seed);
    let levels = [0i32, 1, 2, 4, -1, -2, -4];
    let codes: Vec<Code> = (0..k * oc)
        .map(|_| Code::from_level(levels[r.below(7) as usize]).unwrap())
        .collect();
    let scalars: Vec<f32> = (0..(k / group) * oc)
        .map(|_| (2.0f32).powi(r.range_i64(-2, 2) as i32))
        .collect();
    let qt = QuantizedTensor {
        codes,
        scalars,
        k,
        oc,
        group,
        phi: 4,
        gamma: 0.5,
        delta: 2.0,
        shape: vec![k, oc],
    };
    let xdata: Vec<f32> = (0..m * k).map(|_| r.range_i64(-8, 8) as f32).collect();
    (Tensor::new(vec![m, k], xdata).unwrap(), qt)
}

#[test]
fn prop_qgemm_equals_decode_matmul_exactly_on_dyadic_data() {
    forall(
        25,
        |r| r.next_u64(),
        |&seed| {
            // vary the shape with the seed too
            let m = 1 + (seed % 7) as usize;
            let group = [2usize, 4, 8][(seed % 3) as usize];
            let k = group * (2 + (seed % 5) as usize);
            let oc = 1 + (seed % 9) as usize;
            let (x, qt) = dyadic_case(seed, m, k, oc, group);
            let dec = Tensor::new(vec![k, oc], qt.decode()).unwrap();
            let want = ops::matmul_naive(&x, &dec).unwrap();
            let got = qgemm_qt(&x, &qt).unwrap();
            check(
                got.data() == want.data(),
                &format!("qgemm != oracle at m={m} k={k} oc={oc} group={group}"),
            )?;
            // v2 is exact on dyadic data too, hence bitwise equal to v1
            let got2 = qgemm2_qt(&x, &qt).unwrap();
            check(
                got2.data() == want.data(),
                &format!("qgemm2 != oracle at m={m} k={k} oc={oc} group={group}"),
            )
        },
    );
}

#[test]
fn prop_qgemm2_parallel_bitwise_equals_single_thread_at_band_boundaries() {
    forall(
        20,
        |r| r.next_u64(),
        |&seed| {
            let mut r = Rng::new(seed);
            // shapes that stress banding: m below, at, and just off the
            // thread count, with non-dyadic gaussian data
            let m = 1 + (r.below(11)) as usize;
            let group = [4usize, 8, 16][(seed % 3) as usize];
            let k = group * (1 + r.below(6) as usize);
            let oc = 1 + r.below(14) as usize;
            let w = gen_weights(&mut r, k * oc, 0.3);
            let qt = quantize(&w, &[k, oc], group, 4, AssignMode::SigmaSearch).unwrap();
            let p = PackedQTensorV2::pack(&qt).unwrap();
            let xdata: Vec<f32> = gen_weights(&mut r, m * k, 1.0);
            let x = Tensor::new(vec![m, k], xdata).unwrap();
            let st = qgemm2_threads(&x, &p, 1).unwrap();
            for nt in [2usize, 3, 5, 8] {
                // covers m < bands and m % bands != 0
                let par = qgemm2_threads(&x, &p, nt).unwrap();
                check(
                    par.data() == st.data(),
                    &format!("parallel v2 != single-thread at m={m} k={k} oc={oc} nt={nt}"),
                )?;
            }
            Ok(())
        },
    );
}

#[test]
fn fused_qconv_equals_materialized_oracle_at_model_layer_shapes() {
    // every conv layer shape of both models, VALID (LeNet) and SAME
    // (ConvNet), against pad + full im2col + qgemm2 over the materialized
    // patch matrix — bitwise: chunking only splits patch rows
    let cases: &[(&[usize], &[usize], bool)] = &[
        (&[5, 5, 1, 6], &[2, 28, 28, 1], false),  // lenet c1
        (&[5, 5, 6, 16], &[2, 12, 12, 6], false), // lenet c2
        (&[3, 3, 3, 32], &[2, 32, 32, 3], true),  // convnet k1
        (&[3, 3, 32, 64], &[2, 8, 8, 32], true),  // convnet k3
    ];
    let mut r = Rng::new(0xBEEF);
    let mut scratch = Scratch::new();
    for &(wshape, xshape, same) in cases {
        let nw: usize = wshape.iter().product();
        let w = gen_weights(&mut r, nw, 0.2);
        let group = Grouping::nearest_divisor(wshape, 16).unwrap();
        let qt = quantize(&w, wshape, group, 4, AssignMode::SigmaSearch).unwrap();
        let p = PackedQTensorV2::pack(&qt).unwrap();
        let nx: usize = xshape.iter().product();
        let x = Tensor::new(xshape.to_vec(), gen_weights(&mut r, nx, 1.0)).unwrap();

        let (kh, kw) = (wshape[0], wshape[1]);
        let padded;
        let xin = if same {
            padded = ops::pad_hw(&x, kh / 2).unwrap();
            &padded
        } else {
            &x
        };
        let (patches, oh, ow) = ops::im2col(xin, kh, kw).unwrap();
        let want = qgemm2(&patches, &p).unwrap();
        let got = qconv(&x, &p, same, &mut scratch).unwrap();
        assert_eq!(got.shape(), &[xshape[0], oh, ow, wshape[3]], "{wshape:?} same={same}");
        assert_eq!(got.data(), want.data(), "{wshape:?} same={same} diverged from oracle");
    }
    // the arena was shared across all four layers: it must have grown, and
    // growth must have stopped once warm for repeated shapes
    assert!(scratch.stats.allocs > 0 || scratch.stats.reuses > 0);
}

#[test]
fn prop_qgemm_close_on_real_quantized_tensors() {
    forall(
        10,
        |r| gen_weights(r, 96 * 12, 0.2),
        |w| {
            let qt = quantize(w, &[96, 12], 8, 4, AssignMode::SigmaSearch).unwrap();
            let mut r2 = Rng::new(w.len() as u64);
            let xdata: Vec<f32> = (0..16 * 96).map(|_| (r2.normal() * 0.7) as f32).collect();
            let x = Tensor::new(vec![16, 96], xdata).unwrap();
            let dec = Tensor::new(vec![96, 12], qt.decode()).unwrap();
            let want = ops::matmul_naive(&x, &dec).unwrap();
            let got = qgemm_qt(&x, &qt).unwrap();
            let diff = got.max_abs_diff(&want) as f64;
            check(diff < 1e-3, &format!("qgemm drifted from oracle by {diff}"))
        },
    );
}

#[test]
fn prop_blocked_matmul_matches_naive_within_1e5() {
    forall(
        20,
        |r| r.next_u64(),
        |&seed| {
            let mut r = Rng::new(seed);
            let m = 1 + (r.below(96)) as usize;
            let k = 1 + (r.below(300)) as usize;
            let n = 1 + (r.below(200)) as usize;
            let x = Tensor::new(vec![m, k], gen_weights(&mut r, m * k, 1.0)).unwrap();
            let w = Tensor::new(vec![k, n], gen_weights(&mut r, k * n, 1.0)).unwrap();
            let fast = ops::matmul(&x, &w).unwrap();
            let slow = ops::matmul_naive(&x, &w).unwrap();
            let diff = fast.max_abs_diff(&slow) as f64;
            check(diff <= 1e-5, &format!("blocked vs naive diff {diff} at ({m},{k},{n})"))
        },
    );
}

#[test]
fn prop_fast_sigma_search_identical_to_naive_grid() {
    for phi in [1u32, 2, 4] {
        forall(
            8,
            |r| gen_weights(r, 64 * 6, 0.25),
            |w| {
                let fast = quantize(w, &[64, 6], 8, phi, AssignMode::SigmaSearch).unwrap();
                let naive = quantize_sigma_search_naive(w, &[64, 6], 8, phi).unwrap();
                check(
                    fast.gamma == naive.gamma
                        && fast.delta == naive.delta
                        && fast.codes == naive.codes,
                    &format!(
                        "phi={phi}: fast (g={}, d={}) != naive (g={}, d={})",
                        fast.gamma, fast.delta, naive.gamma, naive.delta
                    ),
                )
            },
        );
    }
}

#[test]
fn fast_sigma_search_identical_at_convnet_layer_size() {
    // ConvNet k3: [3,3,32,64] -> [288, 64], the shape the >=10x speedup
    // claim is benchmarked at (benches/bench_kernels.rs)
    let mut r = Rng::new(77);
    let w = gen_weights(&mut r, 288 * 64, 0.1);
    let shape = [3usize, 3, 32, 64];
    let fast = quantize(&w, &shape, 16, 4, AssignMode::SigmaSearch).unwrap();
    let naive = quantize_sigma_search_naive(&w, &shape, 16, 4).unwrap();
    assert_eq!(fast.gamma, naive.gamma);
    assert_eq!(fast.delta, naive.delta);
    assert_eq!(fast.codes, naive.codes);
    assert_eq!(fast.scalars, naive.scalars);
}

#[test]
fn packed_tensor_skips_all_zero_columns() {
    // an all-zero tensor packs to zero entries and qgemm returns zeros
    let qt = quantize(&[0.0f32; 64], &[64, 1], 8, 4, AssignMode::Nearest).unwrap();
    let p = PackedQTensor::pack(&qt).unwrap();
    assert_eq!(p.skipped_fraction(), 1.0);
    let x = Tensor::new(vec![2, 64], vec![1.0; 128]).unwrap();
    let y = qgemm_qt(&x, &qt).unwrap();
    assert!(y.data().iter().all(|&v| v == 0.0));
}
