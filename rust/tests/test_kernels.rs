//! Property tests for the kernels layer (no artifacts needed):
//!
//! (a) code-domain `qgemm` (v1) and `qgemm2` (plane-packed v2) equal the
//!     decode-then-fp32-matmul oracle — exactly on dyadic data (where all
//!     paths are exact in f32, so v1 and v2 are bitwise equal), and within
//!     tight tolerance on real quantized gaussian tensors;
//! (b) the row-parallel v2 kernel is bitwise identical to its single-thread
//!     reference across band-boundary shapes (m < bands, m % bands != 0);
//! (c) the fused `qconv` equals the materialized pad + im2col + qgemm2
//!     oracle bitwise at LeNet and ConvNet layer shapes, VALID and SAME;
//! (d) the blocked/microtiled matmul equals the naive ikj loop within 1e-5
//!     (it is in fact bitwise identical — same reduction order);
//! (e) the O(sort) sigma-search picks the identical (gamma, delta, codes)
//!     as the naive 152-pass grid, including at ConvNet layer sizes;
//! (f) the persistent worker pool: pooled band runs are bitwise identical
//!     to single-thread at band boundaries, the spawn counter freezes after
//!     warm-up, concurrent engines share the pool without deadlock, and
//!     `PALLAS_POOL_THREADS=1` degrades to the serial path;
//! (g) the truncated-CSD shift-and-add kernel (`kernels::csd`): bitwise
//!     equal to matmul over its own decode on ternary data at every digit
//!     budget, pooled runs bitwise equal to serial at band boundaries, and
//!     the `CsdEngine` charges its energy ledger linearly per forward;
//! (h) the engine conformance suite: every `Engine` impl runs the same
//!     synthetic store through one parameterized harness — bitwise against
//!     the naive per-op oracle where the path is exact (f32), within
//!     tolerance over its own decode elsewhere — with the warm-forward
//!     scratch alloc-freeze, bitwise equality across sticky-pinned and
//!     re-dealt band leasing, and the uniform `EngineReport` schema
//!     asserted through the trait, not per-engine APIs;
//! (i) scalar-reference parity: the lane-ized serving forwards agree with
//!     the retained scalar plane-sum oracles (`forward_scalar_reference`)
//!     at ULP scale with identical predictions, and the reference path
//!     counts no forwards and charges no energy.

use qsq_edge::data::synth_store;
use qsq_edge::device::{CsdQuality, QualityConfig};
use qsq_edge::kernels::{
    blocked, csd_gemm_threads, for_each_row_band_on, qconv, qgemm2, qgemm2_qt, qgemm2_threads,
    qgemm_qt, PackedCsdTensor, PackedQTensor, PackedQTensorV2, Pool, Scratch,
};
use qsq_edge::model::meta::ModelKind;
use qsq_edge::quant::codes::Code;
use qsq_edge::quant::qsq::{quantize, quantize_sigma_search_naive, AssignMode, QuantizedTensor};
use qsq_edge::quant::vectorize::Grouping;
use qsq_edge::runtime::host::QuantizedEngine;
use qsq_edge::tensor::{ops, Tensor};
use qsq_edge::util::prop::{check, forall, gen_weights};
use qsq_edge::util::rng::Rng;

/// Random codes + power-of-two scalars + integer activations: every
/// intermediate of both GEMMs is exactly representable in f32.
fn dyadic_case(seed: u64, m: usize, k: usize, oc: usize, group: usize) -> (Tensor, QuantizedTensor) {
    let mut r = Rng::new(seed);
    let levels = [0i32, 1, 2, 4, -1, -2, -4];
    let codes: Vec<Code> = (0..k * oc)
        .map(|_| Code::from_level(levels[r.below(7) as usize]).unwrap())
        .collect();
    let scalars: Vec<f32> = (0..(k / group) * oc)
        .map(|_| (2.0f32).powi(r.range_i64(-2, 2) as i32))
        .collect();
    let qt = QuantizedTensor {
        codes,
        scalars,
        k,
        oc,
        group,
        phi: 4,
        gamma: 0.5,
        delta: 2.0,
        shape: vec![k, oc],
    };
    let xdata: Vec<f32> = (0..m * k).map(|_| r.range_i64(-8, 8) as f32).collect();
    (Tensor::new(vec![m, k], xdata).unwrap(), qt)
}

#[test]
fn prop_qgemm_equals_decode_matmul_exactly_on_dyadic_data() {
    forall(
        25,
        |r| r.next_u64(),
        |&seed| {
            // vary the shape with the seed too
            let m = 1 + (seed % 7) as usize;
            let group = [2usize, 4, 8][(seed % 3) as usize];
            let k = group * (2 + (seed % 5) as usize);
            let oc = 1 + (seed % 9) as usize;
            let (x, qt) = dyadic_case(seed, m, k, oc, group);
            let dec = Tensor::new(vec![k, oc], qt.decode()).unwrap();
            let want = ops::matmul_naive(&x, &dec).unwrap();
            let got = qgemm_qt(&x, &qt).unwrap();
            check(
                got.data() == want.data(),
                &format!("qgemm != oracle at m={m} k={k} oc={oc} group={group}"),
            )?;
            // v2 is exact on dyadic data too, hence bitwise equal to v1
            let got2 = qgemm2_qt(&x, &qt).unwrap();
            check(
                got2.data() == want.data(),
                &format!("qgemm2 != oracle at m={m} k={k} oc={oc} group={group}"),
            )
        },
    );
}

#[test]
fn prop_qgemm2_parallel_bitwise_equals_single_thread_at_band_boundaries() {
    forall(
        20,
        |r| r.next_u64(),
        |&seed| {
            let mut r = Rng::new(seed);
            // shapes that stress banding: m below, at, and just off the
            // thread count, with non-dyadic gaussian data
            let m = 1 + (r.below(11)) as usize;
            let group = [4usize, 8, 16][(seed % 3) as usize];
            let k = group * (1 + r.below(6) as usize);
            let oc = 1 + r.below(14) as usize;
            let w = gen_weights(&mut r, k * oc, 0.3);
            let qt = quantize(&w, &[k, oc], group, 4, AssignMode::SigmaSearch).unwrap();
            let p = PackedQTensorV2::pack(&qt).unwrap();
            let xdata: Vec<f32> = gen_weights(&mut r, m * k, 1.0);
            let x = Tensor::new(vec![m, k], xdata).unwrap();
            let st = qgemm2_threads(&x, &p, 1).unwrap();
            for nt in [2usize, 3, 5, 8] {
                // covers m < bands and m % bands != 0
                let par = qgemm2_threads(&x, &p, nt).unwrap();
                check(
                    par.data() == st.data(),
                    &format!("parallel v2 != single-thread at m={m} k={k} oc={oc} nt={nt}"),
                )?;
            }
            Ok(())
        },
    );
}

#[test]
fn fused_qconv_equals_materialized_oracle_at_model_layer_shapes() {
    // every conv layer shape of both models, VALID (LeNet) and SAME
    // (ConvNet), against pad + full im2col + qgemm2 over the materialized
    // patch matrix — bitwise: chunking only splits patch rows
    let cases: &[(&[usize], &[usize], bool)] = &[
        (&[5, 5, 1, 6], &[2, 28, 28, 1], false),  // lenet c1
        (&[5, 5, 6, 16], &[2, 12, 12, 6], false), // lenet c2
        (&[3, 3, 3, 32], &[2, 32, 32, 3], true),  // convnet k1
        (&[3, 3, 32, 64], &[2, 8, 8, 32], true),  // convnet k3
    ];
    let mut r = Rng::new(0xBEEF);
    let mut scratch = Scratch::new();
    for &(wshape, xshape, same) in cases {
        let nw: usize = wshape.iter().product();
        let w = gen_weights(&mut r, nw, 0.2);
        let group = Grouping::nearest_divisor(wshape, 16).unwrap();
        let qt = quantize(&w, wshape, group, 4, AssignMode::SigmaSearch).unwrap();
        let p = PackedQTensorV2::pack(&qt).unwrap();
        let nx: usize = xshape.iter().product();
        let x = Tensor::new(xshape.to_vec(), gen_weights(&mut r, nx, 1.0)).unwrap();

        let (kh, kw) = (wshape[0], wshape[1]);
        let padded;
        let xin = if same {
            padded = ops::pad_hw(&x, kh / 2).unwrap();
            &padded
        } else {
            &x
        };
        let (patches, oh, ow) = ops::im2col(xin, kh, kw).unwrap();
        let want = qgemm2(&patches, &p).unwrap();
        let got = qconv(&x, &p, same, &mut scratch).unwrap();
        assert_eq!(got.shape(), &[xshape[0], oh, ow, wshape[3]], "{wshape:?} same={same}");
        assert_eq!(got.data(), want.data(), "{wshape:?} same={same} diverged from oracle");
    }
    // the arena was shared across all four layers: it must have grown, and
    // growth must have stopped once warm for repeated shapes
    assert!(scratch.stats.allocs > 0 || scratch.stats.reuses > 0);
}

#[test]
fn prop_qgemm_close_on_real_quantized_tensors() {
    forall(
        10,
        |r| gen_weights(r, 96 * 12, 0.2),
        |w| {
            let qt = quantize(w, &[96, 12], 8, 4, AssignMode::SigmaSearch).unwrap();
            let mut r2 = Rng::new(w.len() as u64);
            let xdata: Vec<f32> = (0..16 * 96).map(|_| (r2.normal() * 0.7) as f32).collect();
            let x = Tensor::new(vec![16, 96], xdata).unwrap();
            let dec = Tensor::new(vec![96, 12], qt.decode()).unwrap();
            let want = ops::matmul_naive(&x, &dec).unwrap();
            let got = qgemm_qt(&x, &qt).unwrap();
            let diff = got.max_abs_diff(&want) as f64;
            check(diff < 1e-3, &format!("qgemm drifted from oracle by {diff}"))
        },
    );
}

#[test]
fn prop_blocked_matmul_matches_naive_within_1e5() {
    forall(
        20,
        |r| r.next_u64(),
        |&seed| {
            let mut r = Rng::new(seed);
            let m = 1 + (r.below(96)) as usize;
            let k = 1 + (r.below(300)) as usize;
            let n = 1 + (r.below(200)) as usize;
            let x = Tensor::new(vec![m, k], gen_weights(&mut r, m * k, 1.0)).unwrap();
            let w = Tensor::new(vec![k, n], gen_weights(&mut r, k * n, 1.0)).unwrap();
            let fast = ops::matmul(&x, &w).unwrap();
            let slow = ops::matmul_naive(&x, &w).unwrap();
            let diff = fast.max_abs_diff(&slow) as f64;
            check(diff <= 1e-5, &format!("blocked vs naive diff {diff} at ({m},{k},{n})"))
        },
    );
}

#[test]
fn prop_fast_sigma_search_identical_to_naive_grid() {
    for phi in [1u32, 2, 4] {
        forall(
            8,
            |r| gen_weights(r, 64 * 6, 0.25),
            |w| {
                let fast = quantize(w, &[64, 6], 8, phi, AssignMode::SigmaSearch).unwrap();
                let naive = quantize_sigma_search_naive(w, &[64, 6], 8, phi).unwrap();
                check(
                    fast.gamma == naive.gamma
                        && fast.delta == naive.delta
                        && fast.codes == naive.codes,
                    &format!(
                        "phi={phi}: fast (g={}, d={}) != naive (g={}, d={})",
                        fast.gamma, fast.delta, naive.gamma, naive.delta
                    ),
                )
            },
        );
    }
}

#[test]
fn fast_sigma_search_identical_at_convnet_layer_size() {
    // ConvNet k3: [3,3,32,64] -> [288, 64], the shape the >=10x speedup
    // claim is benchmarked at (benches/bench_kernels.rs)
    let mut r = Rng::new(77);
    let w = gen_weights(&mut r, 288 * 64, 0.1);
    let shape = [3usize, 3, 32, 64];
    let fast = quantize(&w, &shape, 16, 4, AssignMode::SigmaSearch).unwrap();
    let naive = quantize_sigma_search_naive(&w, &shape, 16, 4).unwrap();
    assert_eq!(fast.gamma, naive.gamma);
    assert_eq!(fast.delta, naive.delta);
    assert_eq!(fast.codes, naive.codes);
    assert_eq!(fast.scalars, naive.scalars);
}

#[test]
fn pooled_bands_bitwise_equal_serial_at_band_boundaries() {
    // the blocked f32 microkernel through private pools of several widths,
    // at shapes that stress banding (m below, at, and just off the width)
    let mut r = Rng::new(0xA11A5);
    let (k, n) = (37, 29);
    let wd = gen_weights(&mut r, k * n, 0.5);
    for m in [1usize, 2, 3, 5, 8, 13] {
        let xd = gen_weights(&mut r, m * k, 1.0);
        let mut serial = vec![0.0f32; m * n];
        blocked::gemm_band(&mut serial, &xd, &wd, k, n);
        for width in [2usize, 3, 5] {
            // pinning only changes which worker a band lands on, never the
            // banding itself, so both leasing modes must stay bitwise equal
            // to the serial run
            for pinned in [true, false] {
                let pool = Pool::new(width);
                pool.set_pinned(pinned);
                let mut pooled = vec![0.0f32; m * n];
                for_each_row_band_on(&pool, &mut pooled, &xd, m, k, n, width, |_, ob, xb| {
                    blocked::gemm_band(ob, xb, &wd, k, n);
                });
                assert_eq!(
                    pooled, serial,
                    "m={m} width={width} pinned={pinned} diverged from serial"
                );
            }
        }
    }
}

#[test]
fn pool_spawns_frozen_across_warm_engine_forwards() {
    // the acceptance invariant: steady-state serving spawns zero threads
    // per request — the global pool's spawn counter must not move across
    // warm QuantizedEngine forwards, and the outputs must stay identical
    let store = synth_store(33, ModelKind::Lenet);
    let quality = QualityConfig { phi: 4, group: 16 };
    let engine = QuantizedEngine::quantize_store(&store, quality, AssignMode::SigmaSearch).unwrap();
    let mut r = Rng::new(34);
    let xdata: Vec<f32> = gen_weights(&mut r, 32 * 28 * 28, 1.0);
    let x = Tensor::new(vec![32, 28, 28, 1], xdata).unwrap();
    let mut scratch = Scratch::new();
    // warm-up: first forward builds the pool (lazily) and grows the arena
    let first = engine.forward_with(&x, &mut scratch).unwrap();
    let warm_spawns = engine.pool().stats().spawns;
    for _ in 0..5 {
        let again = engine.forward_with(&x, &mut scratch).unwrap();
        assert_eq!(again.data(), first.data(), "warm forward changed the result");
    }
    let s = engine.pool().stats();
    assert_eq!(
        s.spawns, warm_spawns,
        "warm forwards must not spawn threads (pool stats: {s:?})"
    );
}

#[test]
fn concurrent_engines_share_the_pool_without_deadlock() {
    // two engines on two threads, both dispatching on the shared global
    // pool; a watchdog timeout turns a deadlock into a failure, not a hang
    let quality = QualityConfig { phi: 4, group: 16 };
    let lenet = QuantizedEngine::quantize_store(
        &synth_store(35, ModelKind::Lenet),
        quality,
        AssignMode::SigmaSearch,
    )
    .unwrap();
    let convnet = QuantizedEngine::quantize_store(
        &synth_store(36, ModelKind::Convnet),
        quality,
        AssignMode::SigmaSearch,
    )
    .unwrap();
    let (tx, rx) = std::sync::mpsc::channel::<bool>();
    // detached (not scoped) threads: on a real deadlock the workers never
    // return, and a scoped join would hang the test past its watchdog —
    // detached, the recv_timeout below fails the test in 120 s and the
    // wedged threads die with the process
    let txa = tx.clone();
    std::thread::spawn(move || {
        let mut r = Rng::new(37);
        let x =
            Tensor::new(vec![16, 28, 28, 1], gen_weights(&mut r, 16 * 28 * 28, 1.0)).unwrap();
        let mut scratch = Scratch::new();
        let want = lenet.forward_with(&x, &mut scratch).unwrap();
        let mut ok = true;
        for _ in 0..6 {
            let got = lenet.forward_with(&x, &mut scratch).unwrap();
            ok &= got.data() == want.data();
        }
        let _ = txa.send(ok);
    });
    let txb = tx;
    std::thread::spawn(move || {
        let mut r = Rng::new(38);
        let x =
            Tensor::new(vec![4, 32, 32, 3], gen_weights(&mut r, 4 * 32 * 32 * 3, 1.0)).unwrap();
        let mut scratch = Scratch::new();
        let want = convnet.forward_with(&x, &mut scratch).unwrap();
        let mut ok = true;
        for _ in 0..6 {
            let got = convnet.forward_with(&x, &mut scratch).unwrap();
            ok &= got.data() == want.data();
        }
        let _ = txb.send(ok);
    });
    for _ in 0..2 {
        let ok = rx
            .recv_timeout(std::time::Duration::from_secs(120))
            .expect("concurrent engine forwards deadlocked on the shared pool");
        assert!(ok, "concurrent forwards diverged from their single-engine results");
    }
}

#[test]
fn pool_threads_env_of_one_degrades_to_serial() {
    // pin the global pool's config first so the env write cannot race its
    // lazy initialization, then build a private pool from the override
    let _ = Pool::global();
    std::env::set_var("PALLAS_POOL_THREADS", "1");
    let pool = Pool::from_env();
    std::env::remove_var("PALLAS_POOL_THREADS");
    assert_eq!(pool.workers(), 0, "PALLAS_POOL_THREADS=1 must spawn no workers");
    // kernels on a width-1 pool run the serial path and still compute
    // correct results
    let mut r = Rng::new(39);
    let (m, k, n) = (9, 21, 17);
    let xd = gen_weights(&mut r, m * k, 1.0);
    let wd = gen_weights(&mut r, k * n, 0.5);
    let mut serial = vec![0.0f32; m * n];
    blocked::gemm_band(&mut serial, &xd, &wd, k, n);
    let mut pooled = vec![0.0f32; m * n];
    for_each_row_band_on(&pool, &mut pooled, &xd, m, k, n, 8, |_, ob, xb| {
        blocked::gemm_band(ob, xb, &wd, k, n);
    });
    assert_eq!(pooled, serial);
    let s = pool.stats();
    assert_eq!((s.spawns, s.wakeups), (0, 0), "serial pool must never spawn or wake");
}

// --- (h) engine conformance suite -------------------------------------------

#[test]
fn engine_conformance_every_impl_on_the_same_store() {
    use qsq_edge::model::store::WeightStore;
    use qsq_edge::runtime::engine::{Engine, EngineKind};
    use qsq_edge::runtime::host::{self, CsdEngine, F32Engine};

    let store = synth_store(61, ModelKind::Lenet);
    let quality = QualityConfig { phi: 4, group: 16 };
    let csd_q = CsdQuality::new(3);

    // each engine's oracle store: the f32 weights its compressed form
    // decodes to (the f32 engine decodes to the store itself)
    let decode_qsq = |store: &WeightStore| {
        let mut decoded = store.clone();
        for tm in store.meta.quantized_tensors() {
            let g = Grouping::nearest_divisor(&tm.shape, quality.group).unwrap();
            let qt = quantize(store.get(tm.name).unwrap().data(), &tm.shape, g, quality.phi,
                AssignMode::SigmaSearch)
            .unwrap();
            decoded.set(tm.name, Tensor::new(tm.shape.clone(), qt.decode()).unwrap()).unwrap();
        }
        decoded
    };
    let decode_csd = |store: &WeightStore| {
        let mut decoded = store.clone();
        for tm in store.meta.quantized_tensors() {
            let p = PackedCsdTensor::pack(store.get(tm.name).unwrap().data(), &tm.shape, csd_q)
                .unwrap();
            decoded.set(tm.name, Tensor::new(tm.shape.clone(), p.decode()).unwrap()).unwrap();
        }
        decoded
    };

    // (engine, oracle store, tolerance): 0.0 = bitwise.  The PJRT wrapper
    // shares the trait but needs compiled artifacts; its parity is covered
    // by tests/test_server.rs when artifacts exist.
    type Case = (Box<dyn Engine>, WeightStore, f32);
    let cases: Vec<Case> = vec![
        (Box::new(F32Engine::new(store.clone())), store.clone(), 0.0),
        (
            Box::new(
                QuantizedEngine::quantize_store(&store, quality, AssignMode::SigmaSearch)
                    .unwrap(),
            ),
            decode_qsq(&store),
            1e-2,
        ),
        (Box::new(CsdEngine::from_store(&store, csd_q).unwrap()), decode_csd(&store), 1e-2),
    ];

    let mut r = Rng::new(62);
    let xdata: Vec<f32> = gen_weights(&mut r, 3 * 28 * 28, 1.0);
    let x = Tensor::new(vec![3, 28, 28, 1], xdata).unwrap();
    let mut seen = Vec::new();
    for (engine, oracle_store, tol) in cases {
        let name = engine.name();
        seen.push(engine.kind());
        assert_eq!(engine.model(), ModelKind::Lenet, "{name}");

        // the naive per-op oracle over the engine's decoded weights
        let want = host::lenet_fwd(&oracle_store, &x).unwrap();
        let mut scratch = Scratch::new();
        let got = engine.forward_with(&x, &mut scratch).unwrap();
        assert_eq!(got.shape(), want.shape(), "{name}");
        if tol == 0.0 {
            assert_eq!(got.data(), want.data(), "{name}: exact path must be bitwise");
        } else {
            let diff = got.max_abs_diff(&want);
            assert!(diff < tol, "{name}: {diff} vs oracle (tol {tol})");
            assert_eq!(
                ops::argmax_rows(&got),
                ops::argmax_rows(&want),
                "{name}: predictions diverged"
            );
        }

        // uniform warm-forward invariant, asserted through the trait: a
        // warm arena allocates nothing and the output never changes
        let cold_allocs = scratch.stats.allocs;
        for _ in 0..3 {
            let again = engine.forward_with(&x, &mut scratch).unwrap();
            assert_eq!(again.data(), got.data(), "{name}: warm forward changed the result");
        }
        assert_eq!(
            scratch.stats.allocs, cold_allocs,
            "{name}: warm forwards must not allocate ({:?})",
            scratch.stats
        );

        // sticky band pinning is placement-only: the same engine on the
        // same pool must stay bitwise identical with pinning on and off
        // (re-dealt leasing); the default (pinned) mode is restored after
        for pinned in [false, true] {
            Pool::global().set_pinned(pinned);
            let again = engine.forward_with(&x, &mut scratch).unwrap();
            assert_eq!(again.data(), got.data(), "{name}: pinned={pinned} changed the result");
        }
        assert!(Pool::global().is_pinned(), "{name}: default pin mode must be restored");

        // uniform report schema: forwards counted, energy charged, pool
        // visible — the same fields for every engine
        let rep = engine.report();
        assert_eq!(rep.kind, engine.kind(), "{name}");
        assert_eq!(rep.name, name);
        assert_eq!(rep.forwards, 6, "{name}: 1 cold + 3 warm + 2 pin-mode forwards");
        assert!(rep.ledger.total_pj() > 0.0, "{name}: every engine charges energy");
        assert!(rep.pool.is_some(), "{name}: host engines report their pool");
        match rep.kind {
            EngineKind::F32 => assert_eq!(rep.mean_pp, 0.0),
            EngineKind::Quantized => {
                assert!(rep.skipped_fraction > 0.0, "qgemm2 must realize zero-skip")
            }
            EngineKind::Csd => {
                assert!(rep.mean_pp > 0.0 && rep.mean_pp <= 3.0 + 1e-12, "pp within the dial")
            }
            EngineKind::Pjrt => unreachable!(),
        }
    }
    assert_eq!(seen, [EngineKind::F32, EngineKind::Quantized, EngineKind::Csd]);
}

// --- (i) lane-vs-scalar reference parity -------------------------------------

#[test]
fn lane_forwards_match_scalar_reference_and_reference_is_free() {
    use qsq_edge::runtime::host::CsdEngine;

    // the serving forwards run the lane-ized plane sums; the reference
    // forwards run the retained single-accumulator scalar oracles through
    // the identical banding and dispatch.  Lanes only reassociate the f32
    // gather within one plane, so parity is ULP-scale on gaussian inputs
    // and predictions must be identical — and the reference path must not
    // count forwards or charge the energy ledger.
    let store = synth_store(63, ModelKind::Lenet);
    let quality = QualityConfig { phi: 4, group: 16 };
    let q = QuantizedEngine::quantize_store(&store, quality, AssignMode::SigmaSearch).unwrap();
    let c = CsdEngine::from_store(&store, CsdQuality::new(3)).unwrap();
    let mut r = Rng::new(64);
    let x = Tensor::new(vec![4, 28, 28, 1], gen_weights(&mut r, 4 * 28 * 28, 1.0)).unwrap();
    let mut scratch = Scratch::new();

    let q_lane = q.forward_with(&x, &mut scratch).unwrap();
    let q_ref = q.forward_scalar_reference(&x, &mut scratch).unwrap();
    let qd = q_lane.max_abs_diff(&q_ref) as f64;
    assert!(qd < 1e-3, "qgemm2 lane vs scalar reference drifted by {qd}");
    assert_eq!(ops::argmax_rows(&q_lane), ops::argmax_rows(&q_ref), "qgemm2 predictions");
    assert_eq!(q.forwards(), 1, "scalar reference must not count a forward");

    let c_lane = c.forward_with(&x, &mut scratch).unwrap();
    let spent = c.ledger().partial_products;
    let c_ref = c.forward_scalar_reference(&x, &mut scratch).unwrap();
    let cd = c_lane.max_abs_diff(&c_ref) as f64;
    assert!(cd < 1e-3, "csd lane vs scalar reference drifted by {cd}");
    assert_eq!(ops::argmax_rows(&c_lane), ops::argmax_rows(&c_ref), "csd predictions");
    assert_eq!(c.forwards(), 1, "scalar reference must not count a forward");
    assert_eq!(
        c.ledger().partial_products,
        spent,
        "scalar reference must not charge the energy ledger"
    );
}

#[test]
fn packed_tensor_skips_all_zero_columns() {
    // an all-zero tensor packs to zero entries and qgemm returns zeros
    let qt = quantize(&[0.0f32; 64], &[64, 1], 8, 4, AssignMode::Nearest).unwrap();
    let p = PackedQTensor::pack(&qt).unwrap();
    assert_eq!(p.skipped_fraction(), 1.0);
    let x = Tensor::new(vec![2, 64], vec![1.0; 128]).unwrap();
    let y = qgemm_qt(&x, &qt).unwrap();
    assert!(y.data().iter().all(|&v| v == 0.0));
}

// --- (g) truncated-CSD shift-and-add kernel ---------------------------------

#[test]
fn prop_csd_gemm_parallel_bitwise_equals_single_thread_at_band_boundaries() {
    forall(
        20,
        |r| r.next_u64(),
        |&seed| {
            let mut r = Rng::new(seed);
            let m = 1 + r.below(11) as usize;
            let k = 8 * (1 + r.below(6) as usize);
            let oc = 1 + r.below(14) as usize;
            let digits = [1usize, 2, 4, usize::MAX][(seed % 4) as usize];
            let w = gen_weights(&mut r, k * oc, 0.3);
            let p = PackedCsdTensor::pack(&w, &[k, oc], CsdQuality::new(digits)).unwrap();
            let xdata: Vec<f32> = gen_weights(&mut r, m * k, 1.0);
            let x = Tensor::new(vec![m, k], xdata).unwrap();
            let st = csd_gemm_threads(&x, &p, 1).unwrap();
            for nt in [2usize, 3, 5, 8] {
                // covers m < bands and m % bands != 0
                let par = csd_gemm_threads(&x, &p, nt).unwrap();
                check(
                    par.data() == st.data(),
                    &format!("parallel csd != single-thread at m={m} k={k} oc={oc} nt={nt}"),
                )?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_csd_gemm_exact_against_its_decode_on_ternary_data() {
    // on {-1, 0, +1} activations both the digit-plane kernel and f32
    // matmul over the packed decode are exact, so they must agree bitwise
    // at every digit budget
    forall(
        20,
        |r| r.next_u64(),
        |&seed| {
            let mut r = Rng::new(seed);
            let m = 1 + (seed % 5) as usize;
            let k = 8 * (1 + r.below(5) as usize);
            let oc = 1 + r.below(9) as usize;
            // no saturation concerns here: the oracle is the packing's own
            // decode, which reflects any fixed-point clamping identically
            let w = gen_weights(&mut r, k * oc, 0.2);
            let xdata: Vec<f32> = (0..m * k).map(|_| r.range_i64(-1, 1) as f32).collect();
            let x = Tensor::new(vec![m, k], xdata).unwrap();
            for digits in [1usize, 3, usize::MAX] {
                let p = PackedCsdTensor::pack(&w, &[k, oc], CsdQuality::new(digits)).unwrap();
                let dec = Tensor::new(vec![k, oc], p.decode()).unwrap();
                let want = ops::matmul_naive(&x, &dec).unwrap();
                let got = qsq_edge::kernels::csd_gemm(&x, &p).unwrap();
                check(
                    got.data() == want.data(),
                    &format!("csd_gemm != decode oracle at m={m} k={k} oc={oc} digits={digits}"),
                )?;
            }
            Ok(())
        },
    );
}

#[test]
fn csd_engine_ledger_accumulates_linearly_and_pool_spawns_stay_frozen() {
    use qsq_edge::runtime::host::CsdEngine;
    let store = synth_store(51, ModelKind::Lenet);
    let engine = CsdEngine::from_store(&store, CsdQuality::new(2)).unwrap();
    let mut r = Rng::new(52);
    let xdata: Vec<f32> = (0..2 * 28 * 28).map(|_| r.f32()).collect();
    let x = Tensor::new(vec![2, 28, 28, 1], xdata).unwrap();
    let mut scratch = Scratch::new();
    let first = engine.forward_with(&x, &mut scratch).unwrap();
    let l1 = engine.ledger();
    assert!(l1.partial_products > 0, "csd layers must spend partial products");
    assert!(engine.mean_pp() <= 2.0 + 1e-12, "pp bounded by the 2-digit dial");
    let warm_spawns = engine.pool().stats().spawns;
    for _ in 0..4 {
        let again = engine.forward_with(&x, &mut scratch).unwrap();
        assert_eq!(again.data(), first.data(), "warm csd forward changed the result");
    }
    let l5 = engine.ledger();
    assert_eq!(l5.partial_products, 5 * l1.partial_products, "ledger must scale linearly");
    assert_eq!(l5.gated_rows, 5 * l1.gated_rows);
    assert_eq!(l5.skipped_macs, 5 * l1.skipped_macs);
    assert_eq!(engine.forwards(), 5);
    assert_eq!(
        engine.pool().stats().spawns,
        warm_spawns,
        "warm csd forwards must not spawn pool threads"
    );
}
