//! Runtime integration: PJRT artifacts vs the pure-rust host oracle, the
//! fused QSQ artifact vs decode-then-forward, and the fc_step semantics.
//!
//! These tests need `artifacts/` (run `make artifacts`); they skip politely
//! when it is absent so `cargo test` works in a fresh checkout.

use std::path::PathBuf;

use qsq_edge::model::meta::ModelKind;
use qsq_edge::model::store::{Dataset, WeightStore};
use qsq_edge::quant::qsq::{quantize, AssignMode};
use qsq_edge::quant::vectorize::Grouping;
use qsq_edge::runtime::client::{ArgValue, Runtime};
use qsq_edge::runtime::host;
use qsq_edge::tensor::{ops, Tensor};

fn artifacts() -> Option<PathBuf> {
    let d = std::env::var("QSQ_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"));
    d.join("manifest.json").exists().then_some(d)
}

macro_rules! need_artifacts {
    () => {
        match artifacts() {
            Some(d) => d,
            None => {
                eprintln!("skipping: no artifacts (run `make artifacts`)");
                return;
            }
        }
    };
}

#[test]
fn pjrt_matches_host_oracle_lenet() {
    let dir = need_artifacts!();
    let mut rt = Runtime::new(&dir).unwrap();
    let store = WeightStore::load(&dir, ModelKind::Lenet).unwrap();
    let test = Dataset::load(&dir, "mnist", "test").unwrap();

    let exe = rt.load("lenet_fwd_b32").unwrap();
    let x = test.batch(0, 32);
    let mut args = vec![ArgValue::F32(x.clone())];
    args.extend(store.ordered().into_iter().map(|t| ArgValue::F32(t.clone())));
    let pjrt_logits = &exe.run(&args).unwrap()[0];

    let host_logits = host::lenet_fwd(&store, &x).unwrap();
    assert_eq!(pjrt_logits.shape(), host_logits.shape());
    let diff = pjrt_logits.max_abs_diff(&host_logits);
    assert!(diff < 1e-2, "PJRT vs host oracle diverge: {diff}");
    // predictions identical
    assert_eq!(ops::argmax_rows(pjrt_logits), ops::argmax_rows(&host_logits));
}

#[test]
fn pjrt_matches_host_oracle_convnet() {
    let dir = need_artifacts!();
    let mut rt = Runtime::new(&dir).unwrap();
    let store = WeightStore::load(&dir, ModelKind::Convnet).unwrap();
    let test = Dataset::load(&dir, "cifar", "test").unwrap();

    let exe = rt.load("convnet_fwd_b32").unwrap();
    let x = test.batch(0, 32);
    let mut args = vec![ArgValue::F32(x.clone())];
    args.extend(store.ordered().into_iter().map(|t| ArgValue::F32(t.clone())));
    let pjrt_logits = &exe.run(&args).unwrap()[0];
    let host_logits = host::convnet_fwd(&store, &x).unwrap();
    let diff = pjrt_logits.max_abs_diff(&host_logits);
    assert!(diff < 5e-2, "PJRT vs host oracle diverge: {diff}");
    assert_eq!(ops::argmax_rows(pjrt_logits), ops::argmax_rows(&host_logits));
}

/// The fused Pallas decode+matmul artifact must equal quantize→decode→fwd.
#[test]
fn fused_qsq_artifact_matches_decode_then_forward() {
    let dir = need_artifacts!();
    let mut rt = Runtime::new(&dir).unwrap();
    let store = WeightStore::load(&dir, ModelKind::Lenet).unwrap();
    let test = Dataset::load(&dir, "mnist", "test").unwrap();
    let x = test.batch(64, 32);

    // the groups baked into the artifact (manifest.models.lenet.qsq_groups)
    let groups: &[(&str, usize)] = &[("c1w", 5), ("c2w", 6), ("f1w", 16), ("f2w", 8)];

    // build fused-artifact args: x, (codes, scalars)*4, fp32 leftovers
    let mut args = vec![ArgValue::F32(x.clone())];
    let mut decoded = store.clone();
    for &(name, g) in groups {
        let tm = store.meta.tensor(name).unwrap().clone();
        let qt = quantize(store.get(name).unwrap().data(), &tm.shape, g, 4, AssignMode::SigmaSearch)
            .unwrap();
        args.push(ArgValue::codes(vec![qt.k, qt.oc], &qt.codes));
        args.push(ArgValue::F32(
            Tensor::new(vec![qt.k / qt.group, qt.oc], qt.scalars.clone()).unwrap(),
        ));
        decoded
            .set(name, Tensor::new(tm.shape.clone(), qt.decode()).unwrap())
            .unwrap();
    }
    for name in ["c1b", "c2b", "f1b", "f2b", "f3w", "f3b"] {
        args.push(ArgValue::F32(store.get(name).unwrap().clone()));
    }

    for artifact in ["lenet_fwd_qsq_b32", "lenet_fwd_qsq_ref_b32"] {
        let exe = rt.load(artifact).unwrap();
        let fused = &exe.run(&args).unwrap()[0];
        let want = host::lenet_fwd(&decoded, &x).unwrap();
        let diff = fused.max_abs_diff(&want);
        assert!(diff < 1e-2, "{artifact} vs decode-then-fwd: {diff}");
        assert_eq!(ops::argmax_rows(fused), ops::argmax_rows(&want), "{artifact}");
    }
}

/// fc_step artifact: loss decreases and the update matches the analytic
/// softmax-CE gradient.
#[test]
fn fc_step_artifact_descends() {
    let dir = need_artifacts!();
    let mut rt = Runtime::new(&dir).unwrap();
    let exe = rt.load("fc_step_b128").unwrap();

    let mut r = qsq_edge::util::rng::Rng::new(0);
    let feat: Vec<f32> = (0..128 * 84).map(|_| (r.normal() * 0.5) as f32).collect();
    let mut y1h = vec![0.0f32; 128 * 10];
    for i in 0..128 {
        y1h[i * 10 + (r.below(10) as usize)] = 1.0;
    }
    let mut w = Tensor::zeros(vec![84, 10]);
    let mut b = Tensor::zeros(vec![10]);
    let mut last = f32::INFINITY;
    for _ in 0..10 {
        let out = exe
            .run(&[
                ArgValue::F32(Tensor::new(vec![128, 84], feat.clone()).unwrap()),
                ArgValue::F32(Tensor::new(vec![128, 10], y1h.clone()).unwrap()),
                ArgValue::F32(w.clone()),
                ArgValue::F32(b.clone()),
                ArgValue::Scalar(0.5),
            ])
            .unwrap();
        let loss = out[0].data()[0];
        assert!(loss <= last + 1e-4, "loss increased: {loss} > {last}");
        last = loss;
        w = out[1].clone();
        b = out[2].clone();
    }
    // started at ln(10), must have descended meaningfully
    assert!(last < 2.0, "loss barely moved: {last}");
}

/// Arg validation: wrong shapes and wrong dtypes are rejected host-side.
#[test]
fn executable_rejects_bad_args() {
    let dir = need_artifacts!();
    let mut rt = Runtime::new(&dir).unwrap();
    let exe = rt.load("lenet_fwd_b1").unwrap();
    // wrong arg count
    assert!(exe.run(&[]).is_err());
    // wrong shape
    let mut args: Vec<ArgValue> = vec![ArgValue::F32(Tensor::zeros(vec![1, 28, 28, 3]))];
    let store = WeightStore::load(&dir, ModelKind::Lenet).unwrap();
    args.extend(store.ordered().into_iter().map(|t| ArgValue::F32(t.clone())));
    assert!(exe.run(&args).is_err());
}

#[test]
fn unknown_artifact_rejected() {
    let dir = need_artifacts!();
    let mut rt = Runtime::new(&dir).unwrap();
    assert!(rt.load("no_such_artifact").is_err());
}
