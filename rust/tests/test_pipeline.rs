//! End-to-end pipeline integration over real artifacts: deploy (quantize →
//! channel → decode) then score on the PJRT runtime; on-device FC fine-tune;
//! quality scalability invariants.

use std::path::PathBuf;

use qsq_edge::channel::LinkConfig;
use qsq_edge::coordinator::{deploy, finetune};
use qsq_edge::device::QualityConfig;
use qsq_edge::model::meta::ModelKind;
use qsq_edge::model::store::{Dataset, WeightStore};
use qsq_edge::quant::qsq::AssignMode;
use qsq_edge::repro;
use qsq_edge::runtime::client::Runtime;

fn artifacts() -> Option<PathBuf> {
    let d = std::env::var("QSQ_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"));
    d.join("manifest.json").exists().then_some(d)
}

macro_rules! need_artifacts {
    () => {
        match artifacts() {
            Some(d) => d,
            None => {
                eprintln!("skipping: no artifacts (run `make artifacts`)");
                return;
            }
        }
    };
}

const EVAL_LIMIT: usize = 512;

#[test]
fn deploy_then_eval_accuracy_degrades_gracefully() {
    let dir = need_artifacts!();
    let mut rt = Runtime::new(&dir).unwrap();
    let store = WeightStore::load(&dir, ModelKind::Lenet).unwrap();
    let test = Dataset::load(&dir, "mnist", "test").unwrap();

    let base = repro::eval_store(&mut rt, &store, &test, EVAL_LIMIT).unwrap();
    let q = QualityConfig { phi: 4, group: 8 };
    let (edge, rep) =
        deploy::deploy(&store, q, AssignMode::SigmaSearch, LinkConfig::default(), 1).unwrap();
    let edge_acc = repro::eval_store(&mut rt, &edge, &test, EVAL_LIMIT).unwrap();

    assert!(base > 0.95, "baseline too low: {base}");
    assert!(edge_acc > base - 0.12, "quantization damaged too much: {base} -> {edge_acc}");
    assert!(edge_acc < base + 1e-9, "quantization cannot improve accuracy here");
    assert!(rep.memory_savings() > 0.7);
}

#[test]
fn deployed_weights_equal_direct_quantization() {
    // channel + container must be transparent: deploy == quantized_store
    let dir = need_artifacts!();
    let store = WeightStore::load(&dir, ModelKind::Lenet).unwrap();
    let q = QualityConfig { phi: 4, group: 16 };
    let (edge, _) =
        deploy::deploy(&store, q, AssignMode::Nearest, LinkConfig::default(), 2).unwrap();
    let names = repro::quantized_names(ModelKind::Lenet);
    let direct = repro::quantized_store(&store, &names, 4, 16, AssignMode::Nearest).unwrap();
    for n in names {
        assert_eq!(
            edge.get(n).unwrap().data(),
            direct.get(n).unwrap().data(),
            "{n} differs between deploy and direct quantization"
        );
    }
}

#[test]
fn quality_scalability_monotone_phi() {
    // Fig.-7 invariant at system level: accuracy(phi=1) <= accuracy(phi=4)
    let dir = need_artifacts!();
    let mut rt = Runtime::new(&dir).unwrap();
    let store = WeightStore::load(&dir, ModelKind::Lenet).unwrap();
    let test = Dataset::load(&dir, "mnist", "test").unwrap();
    let names = repro::quantized_names(ModelKind::Lenet);

    let mut accs = Vec::new();
    for phi in [1u32, 2, 4] {
        let q = repro::quantized_store(&store, &names, phi, 16, AssignMode::Nearest).unwrap();
        accs.push(repro::eval_store(&mut rt, &q, &test, EVAL_LIMIT).unwrap());
    }
    assert!(
        accs[0] <= accs[2] + 0.02,
        "phi=1 ({}) should not beat phi=4 ({}) by more than noise",
        accs[0],
        accs[2]
    );
}

#[test]
fn finetune_recovers_accuracy() {
    let dir = need_artifacts!();
    let mut rt = Runtime::new(&dir).unwrap();
    let store = WeightStore::load(&dir, ModelKind::Lenet).unwrap();
    let train = Dataset::load(&dir, "mnist", "train").unwrap();
    let test = Dataset::load(&dir, "mnist", "test").unwrap();
    let names = repro::quantized_names(ModelKind::Lenet);
    let q = repro::quantized_store(&store, &names, 4, 16, AssignMode::SigmaSearch).unwrap();

    let (_, _, rep) = finetune::finetune_fc(&mut rt, &q, &train, &test, 2, 0.05, 0).unwrap();
    assert!(
        rep.acc_after > rep.acc_before,
        "FC fine-tune did not improve: {} -> {}",
        rep.acc_before,
        rep.acc_after
    );
    assert!(rep.losses.len() == 2 && rep.losses[1] <= rep.losses[0] + 0.05);
}

#[test]
fn noisy_channel_is_transparent_end_to_end() {
    let dir = need_artifacts!();
    let store = WeightStore::load(&dir, ModelKind::Lenet).unwrap();
    let q = QualityConfig { phi: 2, group: 8 };
    let clean = deploy::deploy(&store, q, AssignMode::Nearest, LinkConfig::default(), 5)
        .unwrap()
        .0;
    let noisy_cfg = LinkConfig { ber: 1e-5, ..Default::default() };
    let (noisy, rep) = deploy::deploy(&store, q, AssignMode::Nearest, noisy_cfg, 5).unwrap();
    assert!(rep.transfer.retransmissions > 0, "expected retransmissions at ber=1e-5");
    for n in repro::quantized_names(ModelKind::Lenet) {
        assert_eq!(clean.get(n).unwrap().data(), noisy.get(n).unwrap().data());
    }
}

#[test]
fn manifest_metadata_matches_rust_meta() {
    // guard against python/rust metadata drift
    let dir = need_artifacts!();
    let manifest = qsq_edge::model::store::Manifest::load(&dir).unwrap();
    for kind in [ModelKind::Lenet, ModelKind::Convnet] {
        let meta = qsq_edge::model::meta::ModelMeta::of(kind);
        let m = manifest.root.get("models").get(kind.name());
        let names: Vec<&str> = m
            .get("params")
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_str().unwrap())
            .collect();
        let want: Vec<&str> = meta.tensors.iter().map(|t| t.name).collect();
        assert_eq!(names, want, "{} param order drifted", kind.name());
        for t in &meta.tensors {
            let shape: Vec<usize> = m
                .get("shapes")
                .get(t.name)
                .as_arr()
                .unwrap()
                .iter()
                .map(|v| v.as_usize().unwrap())
                .collect();
            assert_eq!(shape, t.shape, "{}::{} shape drifted", kind.name(), t.name);
        }
        let q: Vec<&str> = m
            .get("quantized")
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_str().unwrap())
            .collect();
        let want_q: Vec<&str> = meta.quantized_tensors().map(|t| t.name).collect();
        assert_eq!(q, want_q, "{} quantized set drifted", kind.name());
    }
}
