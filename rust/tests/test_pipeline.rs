//! End-to-end pipeline integration over real artifacts: deploy (quantize →
//! channel → decode) then score on the PJRT runtime; on-device FC fine-tune;
//! quality scalability invariants.

use std::path::PathBuf;

use qsq_edge::channel::LinkConfig;
use qsq_edge::coordinator::{deploy, finetune};
use qsq_edge::device::QualityConfig;
use qsq_edge::model::meta::ModelKind;
use qsq_edge::model::store::{Dataset, WeightStore};
use qsq_edge::quant::qsq::AssignMode;
use qsq_edge::repro;
use qsq_edge::runtime::client::Runtime;

fn artifacts() -> Option<PathBuf> {
    let d = std::env::var("QSQ_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"));
    d.join("manifest.json").exists().then_some(d)
}

macro_rules! need_artifacts {
    () => {
        match artifacts() {
            Some(d) => d,
            None => {
                eprintln!("skipping: no artifacts (run `make artifacts`)");
                return;
            }
        }
    };
}

const EVAL_LIMIT: usize = 512;

#[test]
fn deploy_then_eval_accuracy_degrades_gracefully() {
    let dir = need_artifacts!();
    let mut rt = Runtime::new(&dir).unwrap();
    let store = WeightStore::load(&dir, ModelKind::Lenet).unwrap();
    let test = Dataset::load(&dir, "mnist", "test").unwrap();

    let base = repro::eval_store(&mut rt, &store, &test, EVAL_LIMIT).unwrap();
    let q = QualityConfig { phi: 4, group: 8 };
    let (edge, rep) =
        deploy::deploy(&store, q, AssignMode::SigmaSearch, LinkConfig::default(), 1).unwrap();
    let edge_acc = repro::eval_store(&mut rt, &edge, &test, EVAL_LIMIT).unwrap();

    assert!(base > 0.95, "baseline too low: {base}");
    assert!(edge_acc > base - 0.12, "quantization damaged too much: {base} -> {edge_acc}");
    assert!(edge_acc < base + 1e-9, "quantization cannot improve accuracy here");
    assert!(rep.memory_savings() > 0.7);
}

#[test]
fn deployed_weights_equal_direct_quantization() {
    // channel + container must be transparent: deploy == quantized_store
    let dir = need_artifacts!();
    let store = WeightStore::load(&dir, ModelKind::Lenet).unwrap();
    let q = QualityConfig { phi: 4, group: 16 };
    let (edge, _) =
        deploy::deploy(&store, q, AssignMode::Nearest, LinkConfig::default(), 2).unwrap();
    let names = repro::quantized_names(ModelKind::Lenet);
    let direct = repro::quantized_store(&store, &names, 4, 16, AssignMode::Nearest).unwrap();
    for n in names {
        assert_eq!(
            edge.get(n).unwrap().data(),
            direct.get(n).unwrap().data(),
            "{n} differs between deploy and direct quantization"
        );
    }
}

#[test]
fn quality_scalability_monotone_phi() {
    // Fig.-7 invariant at system level: accuracy(phi=1) <= accuracy(phi=4)
    let dir = need_artifacts!();
    let mut rt = Runtime::new(&dir).unwrap();
    let store = WeightStore::load(&dir, ModelKind::Lenet).unwrap();
    let test = Dataset::load(&dir, "mnist", "test").unwrap();
    let names = repro::quantized_names(ModelKind::Lenet);

    let mut accs = Vec::new();
    for phi in [1u32, 2, 4] {
        let q = repro::quantized_store(&store, &names, phi, 16, AssignMode::Nearest).unwrap();
        accs.push(repro::eval_store(&mut rt, &q, &test, EVAL_LIMIT).unwrap());
    }
    assert!(
        accs[0] <= accs[2] + 0.02,
        "phi=1 ({}) should not beat phi=4 ({}) by more than noise",
        accs[0],
        accs[2]
    );
}

#[test]
fn finetune_recovers_accuracy() {
    let dir = need_artifacts!();
    let mut rt = Runtime::new(&dir).unwrap();
    let store = WeightStore::load(&dir, ModelKind::Lenet).unwrap();
    let train = Dataset::load(&dir, "mnist", "train").unwrap();
    let test = Dataset::load(&dir, "mnist", "test").unwrap();
    let names = repro::quantized_names(ModelKind::Lenet);
    let q = repro::quantized_store(&store, &names, 4, 16, AssignMode::SigmaSearch).unwrap();

    let (_, _, rep) = finetune::finetune_fc(&mut rt, &q, &train, &test, 2, 0.05, 0).unwrap();
    assert!(
        rep.acc_after > rep.acc_before,
        "FC fine-tune did not improve: {} -> {}",
        rep.acc_before,
        rep.acc_after
    );
    assert!(rep.losses.len() == 2 && rep.losses[1] <= rep.losses[0] + 0.05);
}

#[test]
fn noisy_channel_is_transparent_end_to_end() {
    let dir = need_artifacts!();
    let store = WeightStore::load(&dir, ModelKind::Lenet).unwrap();
    let q = QualityConfig { phi: 2, group: 8 };
    let clean = deploy::deploy(&store, q, AssignMode::Nearest, LinkConfig::default(), 5)
        .unwrap()
        .0;
    let noisy_cfg = LinkConfig { ber: 1e-5, ..Default::default() };
    let (noisy, rep) = deploy::deploy(&store, q, AssignMode::Nearest, noisy_cfg, 5).unwrap();
    assert!(rep.transfer.retransmissions > 0, "expected retransmissions at ber=1e-5");
    for n in repro::quantized_names(ModelKind::Lenet) {
        assert_eq!(clean.get(n).unwrap().data(), noisy.get(n).unwrap().data());
    }
}

#[test]
fn stacked_dials_from_device_profile_end_to_end() {
    // the full stacked-dial story with no artifacts required: a device
    // profile alone picks both quality dials (QSQ from the memory budget,
    // CSD digits from the MACs-derived energy budget), the model is
    // encoded, crosses the profile's channel, and the decoded edge store is
    // served through the truncated-CSD engine — whose logits must track the
    // f32 forward over its own decode, and whose EngineReport carries the
    // energy the dial promised.
    use qsq_edge::data::synth_store;
    use qsq_edge::device::DeviceProfile;
    use qsq_edge::kernels::PackedCsdTensor;
    use qsq_edge::runtime::engine::{Engine, EngineKind};
    use qsq_edge::runtime::host;
    use qsq_edge::tensor::{ops, Tensor};
    use qsq_edge::util::rng::Rng;

    let store = synth_store(81, ModelKind::Lenet);
    let roster = DeviceProfile::roster();
    let device = roster.iter().find(|d| d.name == "edge-fpga-small").unwrap();
    let (engine, rep) =
        deploy::deploy_for_device(&store, device, AssignMode::SigmaSearch, 17).unwrap();

    // the report records both dials, consistent with the profile's own
    // selection and the engine's serving configuration
    let meta = store.meta.clone();
    let (want_q, want_csd, want_act) = device
        .select_quality(
            |phi, g| qsq_edge::model::bits::model_bits(&meta, phi, g).encoded_bits,
            meta.macs_per_image(),
        )
        .unwrap();
    assert_eq!(rep.quality, want_q);
    assert_eq!(rep.csd, Some(want_csd));
    assert_eq!(want_act, 16, "the FPGA class selects the i16 activation dial");
    assert_eq!(engine.quality(), want_csd);
    assert!(want_csd.max_digits >= 1 && want_csd.max_digits != usize::MAX);
    assert!(rep.memory_savings() > 0.5);

    // oracle: replay the same deterministic deployment to get the edge
    // store, stack the CSD decode on its quantized tensors, run f32
    let (edge, _) =
        deploy::deploy(&store, rep.quality, AssignMode::SigmaSearch, device.link, 17).unwrap();
    let mut decoded = edge.clone();
    for tm in store.meta.quantized_tensors() {
        let p = PackedCsdTensor::pack(edge.get(tm.name).unwrap().data(), &tm.shape, want_csd)
            .unwrap();
        decoded
            .set(tm.name, Tensor::new(tm.shape.clone(), p.decode()).unwrap())
            .unwrap();
    }
    let mut r = Rng::new(82);
    let xdata: Vec<f32> = (0..2 * 28 * 28).map(|_| r.f32()).collect();
    let x = Tensor::new(vec![2, 28, 28, 1], xdata).unwrap();
    let got = engine.forward(&x).unwrap();
    let want = host::forward(&decoded, &x).unwrap();
    let diff = got.max_abs_diff(&want);
    assert!(diff < 1e-2, "stacked-dial engine vs its decode: {diff}");
    assert_eq!(ops::argmax_rows(&got), ops::argmax_rows(&want));

    // the uniform EngineReport carries the realized energy of the dial
    let report = (&engine as &dyn Engine).report();
    assert_eq!(report.kind, EngineKind::Csd);
    assert_eq!(report.name, "host-csd");
    assert_eq!(report.forwards, 1);
    assert!(report.ledger.partial_products > 0, "csd layers must spend partial products");
    assert!(report.ledger.fp_muls > 0, "the fp32 head must be charged");
    assert!(report.ledger.total_pj() > 0.0);
    assert!(report.mean_pp > 0.0);
    assert!(
        report.mean_pp <= want_csd.max_digits as f64 + 1e-12,
        "realized pp {} exceeds the selected dial {}",
        report.mean_pp,
        want_csd.max_digits
    );
}

#[test]
fn manifest_metadata_matches_rust_meta() {
    // guard against python/rust metadata drift
    let dir = need_artifacts!();
    let manifest = qsq_edge::model::store::Manifest::load(&dir).unwrap();
    for kind in [ModelKind::Lenet, ModelKind::Convnet] {
        let meta = qsq_edge::model::meta::ModelMeta::of(kind);
        let m = manifest.root.get("models").get(kind.name());
        let names: Vec<&str> = m
            .get("params")
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_str().unwrap())
            .collect();
        let want: Vec<&str> = meta.tensors.iter().map(|t| t.name).collect();
        assert_eq!(names, want, "{} param order drifted", kind.name());
        for t in &meta.tensors {
            let shape: Vec<usize> = m
                .get("shapes")
                .get(t.name)
                .as_arr()
                .unwrap()
                .iter()
                .map(|v| v.as_usize().unwrap())
                .collect();
            assert_eq!(shape, t.shape, "{}::{} shape drifted", kind.name(), t.name);
        }
        let q: Vec<&str> = m
            .get("quantized")
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_str().unwrap())
            .collect();
        let want_q: Vec<&str> = meta.quantized_tensors().map(|t| t.name).collect();
        assert_eq!(q, want_q, "{} quantized set drifted", kind.name());
    }
}
