//! Multiplexed front-end integration: pipelined out-of-order replies keyed
//! by `id`, many concurrent connections on one event loop, slow
//! readers/writers, worker replication (N workers must serve the same
//! predictions as 1), typed bad-request rejection, terminal shed/shutdown
//! replies, and the HTTP ops surface (`/healthz`, Prometheus `/metrics`,
//! `/metrics.json`) — all over synthetic stores, no artifacts needed.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use qsq_edge::coordinator::server::{EngineSelect, Server, ServerConfig};
use qsq_edge::data::{synth_store, RequestGen};
use qsq_edge::model::meta::ModelKind;
use qsq_edge::util::json::{self, Value};

const PIX: usize = 28 * 28; // LeNet input

fn start(cfg: ServerConfig) -> Server {
    Server::start_with_store(synth_store(5, ModelKind::Lenet), cfg).unwrap()
}

fn connect(port: u16) -> (BufReader<TcpStream>, TcpStream) {
    let s = TcpStream::connect(format!("127.0.0.1:{port}")).unwrap();
    s.set_nodelay(true).ok();
    (BufReader::new(s.try_clone().unwrap()), s)
}

/// A valid request line with an all-zeros image (shared fast path for
/// tests that don't care about the prediction value).
fn zeros_line(id: u64) -> String {
    format!("{{\"id\":{id},\"pixels\":[{}]}}\n", vec!["0"; PIX].join(","))
}

fn req_line(id: u64, pixels: &[f32]) -> String {
    let arr = Value::Arr(pixels.iter().map(|&p| json::num(p as f64)).collect());
    json::obj(vec![("id", json::num(id as f64)), ("pixels", arr)]).to_json() + "\n"
}

fn read_reply(r: &mut BufReader<TcpStream>) -> Value {
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    json::parse(line.trim()).unwrap()
}

#[test]
fn pipelined_replies_key_by_id_any_order() {
    let srv = start(ServerConfig::default());
    let (mut r, mut w) = connect(srv.port);
    // fire 32 requests without reading a single reply — pipelining is the
    // contract, and replies come back in *completion* order, so the only
    // valid way to consume them is by id
    let mut gen = RequestGen::new(ModelKind::Lenet, 7);
    for id in 0..32u64 {
        let (img, _) = gen.next();
        w.write_all(req_line(id, img.data()).as_bytes()).unwrap();
    }
    let mut seen = BTreeMap::new();
    for _ in 0..32 {
        let v = read_reply(&mut r);
        assert!(v.get("error").is_null(), "{}", v.to_json());
        let id = v.get("id").as_f64().unwrap() as u64;
        let pred = v.get("pred").as_f64().unwrap();
        assert!((0.0..10.0).contains(&pred));
        assert!(seen.insert(id, pred).is_none(), "duplicate reply for id {id}");
    }
    assert_eq!(seen.keys().copied().collect::<Vec<_>>(), (0..32).collect::<Vec<_>>());
    srv.stop();
}

#[test]
fn sixty_four_plus_connections_multiplexed_concurrently() {
    // the acceptance bar: >= 64 connections open at once, every one with
    // pipelined unanswered requests, all on one event-loop thread
    let srv = start(ServerConfig::default());
    let mut conns: Vec<(BufReader<TcpStream>, TcpStream)> =
        (0..72).map(|_| connect(srv.port)).collect();
    // all connections write both their requests before any reply is read
    for (c, (_, w)) in conns.iter_mut().enumerate() {
        let base = c as u64 * 100;
        w.write_all(zeros_line(base).as_bytes()).unwrap();
        w.write_all(zeros_line(base + 1).as_bytes()).unwrap();
    }
    for (c, (r, _)) in conns.iter_mut().enumerate() {
        let base = c as u64 * 100;
        let mut got: Vec<u64> = (0..2)
            .map(|_| {
                let v = read_reply(r);
                assert!(v.get("error").is_null(), "conn {c}: {}", v.to_json());
                v.get("id").as_f64().unwrap() as u64
            })
            .collect();
        got.sort_unstable();
        assert_eq!(got, vec![base, base + 1], "conn {c}");
    }
    assert_eq!(srv.metrics.counter("requests"), 144);
    srv.stop();
}

#[test]
fn slow_writer_dribbling_bytes_still_parses() {
    // one request split across many tiny TCP segments: the mux must
    // reassemble the line, never treating a partial read as a request
    let srv = start(ServerConfig::default());
    let (mut r, mut w) = connect(srv.port);
    let line = zeros_line(9);
    for chunk in line.as_bytes().chunks(97) {
        w.write_all(chunk).unwrap();
        w.flush().unwrap();
        std::thread::sleep(Duration::from_millis(2));
    }
    let v = read_reply(&mut r);
    assert_eq!(v.get("id").as_f64(), Some(9.0));
    assert!(v.get("pred").as_f64().is_some(), "{}", v.to_json());
    srv.stop();
}

#[test]
fn slow_reader_gets_every_pipelined_reply() {
    // a reader that doesn't drain for a while: replies queue in the write
    // buffer (and socket), nothing is lost, the loop never stalls on us
    let srv = start(ServerConfig::default());
    let (mut r, mut w) = connect(srv.port);
    for id in 0..16u64 {
        w.write_all(zeros_line(id).as_bytes()).unwrap();
    }
    std::thread::sleep(Duration::from_millis(300)); // all 16 served, unread
    let mut ids: Vec<u64> = (0..16)
        .map(|_| {
            let v = read_reply(&mut r);
            assert!(v.get("error").is_null(), "{}", v.to_json());
            v.get("id").as_f64().unwrap() as u64
        })
        .collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..16).collect::<Vec<_>>());
    srv.stop();
}

/// Serve one fixed request set and collect the id -> pred map.
fn preds_with_workers(workers: usize) -> BTreeMap<u64, f64> {
    let cfg = ServerConfig {
        // pinned to the pure-f32 host engine: the parity claim is about
        // worker replication, not dispatch-policy routing
        engine: EngineSelect::Host,
        workers,
        ..Default::default()
    };
    let srv = start(cfg);
    // 8 connections x 8 pipelined requests, so replicated workers really
    // serve interleaved batches
    let handles: Vec<_> = (0..8u64)
        .map(|c| {
            let port = srv.port;
            std::thread::spawn(move || {
                let (mut r, mut w) = connect(port);
                let mut gen = RequestGen::new(ModelKind::Lenet, 100 + c);
                for i in 0..8u64 {
                    let (img, _) = gen.next();
                    w.write_all(req_line(c * 1000 + i, img.data()).as_bytes()).unwrap();
                }
                (0..8)
                    .map(|_| {
                        let v = read_reply(&mut r);
                        assert!(v.get("error").is_null(), "{}", v.to_json());
                        (
                            v.get("id").as_f64().unwrap() as u64,
                            v.get("pred").as_f64().unwrap(),
                        )
                    })
                    .collect::<Vec<_>>()
            })
        })
        .collect();
    let mut out = BTreeMap::new();
    for h in handles {
        for (id, pred) in h.join().unwrap() {
            out.insert(id, pred);
        }
    }
    srv.stop();
    out
}

#[test]
fn replicated_workers_match_single_worker_predictions() {
    // row-band kernels compute each output row independently, so however
    // the dynamic batcher groups requests and whichever worker serves each
    // batch, the logits per request are bitwise identical — N workers must
    // reproduce the single-worker predictions exactly
    let one = preds_with_workers(1);
    let four = preds_with_workers(4);
    assert_eq!(one.len(), 64);
    assert_eq!(one, four, "worker replication changed served predictions");
}

#[test]
fn overload_sheds_are_terminal_and_counted() {
    let cfg = ServerConfig {
        batch: 2,
        queue_cap: 2,
        max_delay: Duration::from_millis(1),
        workers: 4,
        ..Default::default()
    };
    let srv = start(cfg);
    let (mut r, mut w) = connect(srv.port);
    let n = 400u64;
    for id in 0..n {
        w.write_all(zeros_line(id).as_bytes()).unwrap();
    }
    let (mut preds, mut sheds) = (0u64, 0u64);
    let mut seen = std::collections::BTreeSet::new();
    for _ in 0..n {
        let v = read_reply(&mut r);
        assert!(seen.insert(v.get("id").as_f64().unwrap() as u64), "{}", v.to_json());
        if v.get("pred").as_f64().is_some() {
            preds += 1;
        } else {
            let e = v.get("error").as_str().unwrap();
            assert!(
                e == "overloaded" || e == "deadline exceeded",
                "unexpected terminal reply: {e}"
            );
            if e == "overloaded" {
                // the shed carries an actionable backoff hint
                assert!(v.get("retry_after_ms").as_f64().unwrap() >= 1.0);
            }
            sheds += 1;
        }
    }
    assert_eq!(preds + sheds, n, "every offered request got exactly one terminal reply");
    assert!(sheds > 0, "a cap-2 queue under a 400-request burst must shed");
    assert!(preds > 0, "admission control must not starve the served path");
    assert_eq!(
        srv.metrics.counter("shed_overload") + srv.metrics.counter("shed_deadline"),
        sheds
    );
    srv.stop();
}

#[test]
fn shutdown_replies_are_terminal_under_replication() {
    let cfg = ServerConfig {
        batch: 64,
        max_delay: Duration::from_secs(5), // jobs sit queued until stop()
        workers: 4,
        ..Default::default()
    };
    let srv = start(cfg);
    let (mut r, mut w) = connect(srv.port);
    for id in 0..10u64 {
        w.write_all(zeros_line(id).as_bytes()).unwrap();
    }
    std::thread::sleep(Duration::from_millis(200)); // all 10 admitted, none served
    let m = srv.metrics.clone();
    srv.stop();
    // stop() drained the backlog: every queued job answered explicitly —
    // clients never hang out a reply timeout on shutdown
    let mut ids = Vec::new();
    for _ in 0..10 {
        let v = read_reply(&mut r);
        assert_eq!(v.get("error").as_str(), Some("server shutting down"), "{}", v.to_json());
        ids.push(v.get("id").as_f64().unwrap() as u64);
    }
    ids.sort_unstable();
    assert_eq!(ids, (0..10).collect::<Vec<_>>());
    assert_eq!(m.counter("shed_shutdown"), 10);
    // and the socket closes cleanly afterwards
    let mut line = String::new();
    assert_eq!(r.read_line(&mut line).unwrap(), 0, "EOF after drain");
}

#[test]
fn bad_requests_are_typed_and_counted() {
    let cfg = ServerConfig {
        batch: 64,
        max_delay: Duration::from_millis(500),
        ..Default::default()
    };
    let srv = start(cfg);
    let (mut r, mut w) = connect(srv.port);

    // a valid request that will sit in the batching window...
    w.write_all(zeros_line(5).as_bytes()).unwrap();
    // ...so a second use of its id is a *duplicate in flight* — the bugfix:
    // admitting it would key two replies to one slot
    w.write_all(zeros_line(5).as_bytes()).unwrap();
    w.write_all(b"{\"pixels\":[1,2]}\n").unwrap(); // missing id
    w.write_all(b"{\"id\":1.5,\"pixels\":[1,2]}\n").unwrap(); // non-integer id
    w.write_all(b"{\"id\":6,\"pixels\":[1,2]}\n").unwrap(); // wrong pixel count

    let mut errors = Vec::new();
    let mut pred_id = None;
    for _ in 0..5 {
        let v = read_reply(&mut r);
        match v.get("error").as_str() {
            Some(e) => errors.push((e.to_string(), v.get("id").as_f64())),
            None => pred_id = v.get("id").as_f64(),
        }
    }
    assert_eq!(pred_id, Some(5.0), "the original request still serves");
    assert_eq!(errors.len(), 4);
    let texts: Vec<&str> = errors.iter().map(|(e, _)| e.as_str()).collect();
    assert!(texts.iter().any(|e| e.contains("duplicate id 5")), "{texts:?}");
    assert!(texts.contains(&"missing id"), "{texts:?}");
    assert!(texts.contains(&"id must be a non-negative integer"), "{texts:?}");
    assert!(texts.iter().any(|e| e.contains("expected 784 pixels")), "{texts:?}");
    // the duplicate-id rejection echoes the id; the id-less rejections can't
    let dup = errors.iter().find(|(e, _)| e.contains("duplicate")).unwrap();
    assert_eq!(dup.1, Some(5.0));
    assert_eq!(srv.metrics.counter("bad_requests"), 4);

    // once id 5's reply has been delivered it is no longer in flight —
    // reusing the id on the same connection is legal again
    w.write_all(zeros_line(5).as_bytes()).unwrap();
    let v = read_reply(&mut r);
    assert!(v.get("pred").as_f64().is_some(), "{}", v.to_json());
    assert_eq!(srv.metrics.counter("bad_requests"), 4, "no new rejection");
    srv.stop();
}

/// Issue one HTTP GET and return the full raw response.
fn http_get(port: u16, path: &str) -> String {
    let mut s = TcpStream::connect(format!("127.0.0.1:{port}")).unwrap();
    s.write_all(format!("GET {path} HTTP/1.1\r\nHost: qsq\r\n\r\n").as_bytes()).unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).unwrap(); // Connection: close -> EOF
    out
}

fn http_body(resp: &str) -> &str {
    resp.split("\r\n\r\n").nth(1).unwrap_or("")
}

#[test]
fn healthz_and_metrics_served_over_http() {
    let cfg = ServerConfig { workers: 2, ..Default::default() };
    let srv = start(cfg);
    // put some traffic through so every metric family has content
    let (mut r, mut w) = connect(srv.port);
    for id in 0..4u64 {
        w.write_all(zeros_line(id).as_bytes()).unwrap();
    }
    for _ in 0..4 {
        let v = read_reply(&mut r);
        assert!(v.get("error").is_null());
    }

    let h = http_get(srv.port, "/healthz");
    assert!(h.starts_with("HTTP/1.1 200 OK\r\n"), "{h}");
    let hv = json::parse(http_body(&h).trim()).unwrap();
    assert_eq!(hv.get("status").as_str(), Some("ok"));
    assert_eq!(hv.get("workers").as_f64(), Some(2.0));
    assert_eq!(hv.get("generation").as_f64(), Some(1.0));

    let m = http_get(srv.port, "/metrics");
    assert!(m.contains("text/plain; version=0.0.4"), "{m}");
    let mb = http_body(&m);
    assert!(mb.contains("# TYPE qsq_requests_total counter"), "{mb}");
    assert!(mb.contains("qsq_requests_total 4"), "{mb}");
    assert!(mb.contains("# TYPE qsq_swap_generation gauge"), "{mb}");
    assert!(mb.contains("# TYPE qsq_infer_batch_seconds summary"), "{mb}");
    assert!(mb.contains("quantile=\"0.999\""), "{mb}");

    let j = http_get(srv.port, "/metrics.json");
    let jv = json::parse(http_body(&j).trim()).unwrap();
    assert!(jv.get("counter.requests").as_f64().is_some(), "{}", jv.to_json());

    assert!(http_get(srv.port, "/nope").starts_with("HTTP/1.1 404"));
    srv.stop();
}
