//! Fuzz-style regression suite for the QSQ1 container decoder.
//!
//! The container is the only bytes-from-the-wire surface in the system — a
//! burst of channel noise that slips past a frame CRC, a truncated transfer,
//! or an outright hostile payload all land in `decode_model`.  The decoder's
//! contract is: **return `Err`, never panic, never allocate from unvalidated
//! counts**.  These tests hammer that contract with deterministic, seeded
//! corpora — every failure reproduces from the seed in the assert message.
//!
//! (Not a coverage-guided fuzzer — the container format is small enough that
//! seeded truncation + bit-flip + garbage sweeps exercise every parse path;
//! see the bounds-scan phase in `codec::container`.)

use qsq_edge::codec::{decode_model, encode_model};
use qsq_edge::coordinator::deploy::encode_store;
use qsq_edge::data::synth_store;
use qsq_edge::device::QualityConfig;
use qsq_edge::model::meta::ModelKind;
use qsq_edge::quant::qsq::AssignMode;
use qsq_edge::util::rng::Rng;

/// One canonical well-formed container all corpora derive from.
fn sample_container() -> Vec<u8> {
    let store = synth_store(9, ModelKind::Lenet);
    let encoded = encode_store(
        &store,
        QualityConfig { phi: 4, group: 16 },
        AssignMode::SigmaSearch,
    )
    .expect("encode");
    encode_model(&encoded).expect("serialize")
}

#[test]
fn roundtrip_is_clean() {
    // the corpus seed itself must decode — otherwise every test below is
    // vacuously "never panics"
    let bytes = sample_container();
    let decoded = decode_model(&bytes).expect("well-formed container decodes");
    assert!(!decoded.tensors.is_empty());
}

#[test]
fn every_truncation_errors_without_panicking() {
    let bytes = sample_container();
    // all short prefixes near the interesting boundaries, plus a stride
    // through the body (step 257 is odd, so it hits every byte alignment)
    let mut lens: Vec<usize> = (0..64.min(bytes.len())).collect();
    lens.extend((64..bytes.len()).step_by(257));
    lens.extend(bytes.len().saturating_sub(8)..bytes.len());
    for len in lens {
        let r = decode_model(&bytes[..len]);
        assert!(r.is_err(), "truncation to {len} bytes must be rejected");
    }
}

#[test]
fn random_bit_flips_never_panic_and_never_pass() {
    let bytes = sample_container();
    let mut rng = Rng::new(0xF1_1B);
    for iter in 0..500 {
        let mut bad = bytes.clone();
        // 1-4 flips per iteration: single-bit errors and small clusters
        let flips = 1 + rng.below(4) as usize;
        for _ in 0..flips {
            let i = rng.below(bad.len() as u64) as usize;
            bad[i] ^= 1 << rng.below(8);
        }
        if bad == bytes {
            continue; // flips cancelled out
        }
        // any corruption must be caught by a CRC (section or total) or a
        // structural check — never served, never a panic.  decode_model
        // checks the total CRC over the whole body, so even flips in
        // already-parsed section bytes cannot slip through.
        let r = decode_model(&bad);
        assert!(r.is_err(), "iter {iter}: corrupted container must not decode");
    }
}

#[test]
fn garbage_buffers_never_panic() {
    let mut rng = Rng::new(0x6A_2B);
    for _ in 0..300 {
        let len = rng.below(4096) as usize;
        let garbage: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
        // overwhelmingly rejected at the magic check; the rare buffer that
        // starts with the magic must still die in the bounds scan
        let _ = decode_model(&garbage);
    }
    // hostile-but-plausible: correct magic + version, garbage after
    for iter in 0..200 {
        let len = 6 + rng.below(2048) as usize;
        let mut buf: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
        buf[0..4].copy_from_slice(b"QSQ1");
        assert!(
            decode_model(&buf).is_err(),
            "iter {iter}: magic-prefixed garbage must be rejected"
        );
    }
}

#[test]
fn section_crc_failures_name_the_offending_tensor() {
    // flip one bit at a stride through the body: every flip must be
    // rejected, and flips inside tensor sections must usually be attributed
    // to a named section by the per-section CRC (flips in the header or
    // trailing CRC words produce other, equally terminal errors)
    let bytes = sample_container();
    let mut named = 0usize;
    let mut total = 0usize;
    for i in (8..bytes.len().saturating_sub(4)).step_by(101) {
        let mut bad = bytes.clone();
        bad[i] ^= 0x10;
        let err = decode_model(&bad).expect_err("flip must be rejected");
        total += 1;
        if format!("{err:#}").contains("section CRC mismatch") {
            named += 1;
        }
    }
    assert!(total > 10, "stride must actually sample the container");
    assert!(
        named > 0,
        "some in-section flips must be attributed by the per-section CRC"
    );
}
