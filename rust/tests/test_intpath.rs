//! Differential gate for the integer-activation datapath (the i16
//! fixed-point ping/pong planes):
//!
//! (a) `gather_sum_i16` vs its scalar oracle — **bitwise** at every
//!     chunk/tail boundary (integer additions are exact in any order), plus
//!     overflow-adversarial all-extremal gathers longer than one chunk;
//! (b) calibration-layer properties: activation quantization **saturates,
//!     never wraps** (a value past the calibrated range clips to the format
//!     extreme with its sign intact), in-range values round-trip within
//!     half a raw step, and the integer ReLU epilogue clamps to `[0,
//!     max_raw]` at both ends;
//! (c) kernel-level differential: the SWAR `qgemm2_i16` / `csd_gemm_i16`
//!     entry points vs their `*_scalar_on` twins — bitwise on every input,
//!     under a serial and a wide pool;
//! (d) engine-level conformance: a calibrated `QuantizedEngine` /
//!     `CsdEngine` integer forward tracks its own f32 scalar oracle
//!     (tolerance + identical argmax), is **bitwise** equal to the integer
//!     scalar reference, freezes scratch allocations once warm, and
//!     calibration itself is a pure fold (same batch ⇒ same plan, same
//!     logits, across engines and recalibrations).
//!
//! CI runs this suite under the default pool and `PALLAS_POOL_THREADS=1`,
//! so the engine-level paths execute both banded and fully serial.

use qsq_edge::data::synth_store;
use qsq_edge::device::{CsdQuality, QualityConfig};
use qsq_edge::hw::fixedpoint::Format;
use qsq_edge::kernels::lanes::{
    gather_sum_i16, gather_sum_i16_scalar, I16_GATHER_CHUNK, I16_LANES,
};
use qsq_edge::kernels::{
    bias_relu_quantize_into, csd_gemm_i16_into_on, csd_gemm_i16_scalar_on, dequant_scale,
    format_for_max_abs, qgemm2_i16_into_on, qgemm2_i16_scalar_on, quantize_into, PackedCsdTensor,
    PackedQTensorV2, Pool, Scratch, ACT_TOTAL_BITS,
};
use qsq_edge::model::meta::ModelKind;
use qsq_edge::quant::qsq::{quantize, AssignMode};
use qsq_edge::runtime::host::{CsdEngine, QuantizedEngine};
use qsq_edge::tensor::{ops, Tensor};
use qsq_edge::util::prop::{check, forall, gen_weights};
use qsq_edge::util::rng::Rng;

/// Lengths that straddle every fast-path boundary of the i16 gather: the
/// SWAR-lane edge, the fixed gather-chunk edge, and a multi-chunk tail.
fn gather_boundary_lengths() -> Vec<usize> {
    vec![
        0,
        1,
        I16_LANES - 1,
        I16_LANES,
        I16_LANES + 1,
        I16_GATHER_CHUNK - 1,
        I16_GATHER_CHUNK,
        I16_GATHER_CHUNK + 1,
        2 * I16_GATHER_CHUNK + 3,
    ]
}

// --- (a) the SWAR i16 gather --------------------------------------------------

#[test]
fn prop_gather_sum_i16_bitwise_scalar_at_every_boundary() {
    forall(
        20,
        |r| r.next_u64(),
        |&seed| {
            let mut r = Rng::new(seed);
            let xs: Vec<i16> = (0..700)
                .map(|_| r.range_i64(i16::MIN as i64, i16::MAX as i64) as i16)
                .collect();
            for len in gather_boundary_lengths() {
                let offsets: Vec<u16> = (0..len).map(|_| r.below(700) as u16).collect();
                check(
                    gather_sum_i16(&offsets, &xs) == gather_sum_i16_scalar(&offsets, &xs),
                    &format!("i16 gather len={len} diverged (seed {seed})"),
                )?;
            }
            Ok(())
        },
    );
}

#[test]
fn gather_i16_extremes_survive_past_the_chunk() {
    // every offset lands on one extreme value, for lengths past several
    // gather chunks: a missed widen inside the chunked reduction would
    // wrap here instead of summing exactly
    for v in [i16::MIN, i16::MAX] {
        let xs = [v; 4];
        let n = 4 * I16_GATHER_CHUNK + 5;
        let offsets: Vec<u16> = (0..n).map(|i| (i % 4) as u16).collect();
        assert_eq!(
            gather_sum_i16(&offsets, &xs),
            v as i64 * n as i64,
            "i16 gather wrapped on {n} extremes of {v}"
        );
    }
    // alternating extremes: worst-case biased lane magnitude, near-zero sum
    let xs = [i16::MIN, i16::MAX];
    let offsets: Vec<u16> = (0..3 * I16_GATHER_CHUNK).map(|i| (i % 2) as u16).collect();
    assert_eq!(gather_sum_i16(&offsets, &xs), gather_sum_i16_scalar(&offsets, &xs));
}

// --- (b) calibration-layer saturation properties ------------------------------

#[test]
fn prop_activation_quantization_saturates_never_wraps() {
    forall(
        30,
        |r| r.next_u64(),
        |&seed| {
            let mut r = Rng::new(seed);
            let ma = 0.01 + r.f32() * 100.0;
            let fmt = format_for_max_abs(ma);
            check(fmt.total == ACT_TOTAL_BITS, "activation formats are 16-bit")?;
            let (lo, hi) = (fmt.min_raw(), fmt.max_raw());

            // a mix of in-range, out-of-range, and absurdly out-of-range
            let mut xs: Vec<f32> =
                (0..64).map(|_| (r.normal() * 2.0 * ma as f64) as f32).collect();
            xs.extend_from_slice(&[ma * 1e6, -ma * 1e6, f32::MAX, f32::MIN]);
            let mut q = vec![0i16; xs.len()];
            quantize_into(&xs, fmt, &mut q);
            for (&v, &raw) in xs.iter().zip(&q) {
                let raw = raw as i64;
                check(
                    (lo..=hi).contains(&raw),
                    &format!("raw {raw} escaped [{lo}, {hi}] for v={v} (seed {seed})"),
                )?;
                // saturation keeps the sign: a clipped positive can never
                // come back negative (the wrap a bare `as i16` would take)
                check(
                    v <= 0.0 || raw >= 0,
                    &format!("positive v={v} wrapped to raw {raw} (seed {seed})"),
                )?;
                check(
                    v >= 0.0 || raw <= 0,
                    &format!("negative v={v} wrapped to raw {raw} (seed {seed})"),
                )?;
            }
            // the absurd values sit exactly on the format extremes
            let n = q.len();
            check(q[n - 2] as i64 == hi && q[n - 1] as i64 == lo, "extremes must saturate")?;

            // in-range values round-trip within half a raw step
            let dq = dequant_scale(fmt);
            let in_range: Vec<f32> =
                (0..64).map(|_| (r.f32() * 2.0 - 1.0) * 0.95 * ma).collect();
            let mut qr = vec![0i16; in_range.len()];
            quantize_into(&in_range, fmt, &mut qr);
            for (&v, &raw) in in_range.iter().zip(&qr) {
                let back = raw as f32 * dq;
                check(
                    (back - v).abs() <= 0.75 * dq + 1e-6,
                    &format!("roundtrip {v} -> {raw} -> {back} off by more than a half step"),
                )?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_integer_epilogue_clamps_at_both_ends() {
    forall(
        30,
        |r| r.next_u64(),
        |&seed| {
            let mut r = Rng::new(seed);
            let fmt = Format { total: ACT_TOTAL_BITS, frac: r.below(16) as u32 };
            let hi = fmt.max_raw();
            let n = 1 + r.below(9) as usize;
            let rows = 1 + r.below(4) as usize;
            let bias_q: Vec<i32> = (0..n).map(|_| r.range_i64(-1000, 1000) as i32).collect();
            let acc = gen_weights(&mut r, rows * n, 1e4);
            let mut dst = vec![0i16; rows * n];
            bias_relu_quantize_into(&acc, &bias_q, fmt, &mut dst);
            for &d in &dst {
                check(
                    (0..=hi).contains(&(d as i64)),
                    &format!("epilogue raw {d} escaped [0, {hi}] (seed {seed})"),
                )?;
            }
            // deterministic extremes: a huge positive pre-activation pins
            // the format max, a huge negative one pins the ReLU floor
            let extremes = [1e30f32, -1e30];
            let mut d2 = vec![0i16; 2];
            bias_relu_quantize_into(&extremes, &[0], fmt, &mut d2);
            check(d2[0] as i64 == hi && d2[1] == 0, "extreme epilogue inputs must clamp")?;
            Ok(())
        },
    );
}

// --- (c) kernel-level i16 lane-vs-scalar differential -------------------------

#[test]
fn qgemm2_i16_lane_and_scalar_are_bitwise_under_both_pool_widths() {
    let mut r = Rng::new(0x17B1);
    let (k, oc, group, m) = (96usize, 14usize, 16usize, 9usize);
    let w = gen_weights(&mut r, k * oc, 0.3);
    let qt = quantize(&w, &[k, oc], group, 4, AssignMode::SigmaSearch).unwrap();
    let p = PackedQTensorV2::pack(&qt).unwrap();
    let xq: Vec<i16> = (0..m * k).map(|_| r.range_i64(-512, 512) as i16).collect();
    let dq = 1.0 / 256.0f32;
    for width in [1usize, 4] {
        let pool = Pool::new(width);
        let mut lane = vec![0.0f32; m * oc];
        let mut scalar = vec![0.0f32; m * oc];
        qgemm2_i16_into_on(&pool, &mut lane, &xq, m, &p, dq);
        qgemm2_i16_scalar_on(&pool, &mut scalar, &xq, m, &p, dq);
        // the plane sums are exact i64 in both orders and both paths apply
        // the same one dequant multiply per cell, so equality is bitwise
        assert_eq!(lane, scalar, "qgemm2 i16 lane vs scalar diverged (width {width})");
        assert!(lane.iter().any(|&v| v != 0.0), "degenerate case: all-zero output");
    }
}

#[test]
fn csd_gemm_i16_lane_and_scalar_are_bitwise_under_both_pool_widths() {
    let mut r = Rng::new(0x17B2);
    let (k, oc, m) = (80usize, 11usize, 7usize);
    let w = gen_weights(&mut r, k * oc, 0.25);
    let p = PackedCsdTensor::pack(&w, &[k, oc], CsdQuality::new(3)).unwrap();
    let xq: Vec<i16> = (0..m * k).map(|_| r.range_i64(-512, 512) as i16).collect();
    let dq = 1.0 / 128.0f32;
    for width in [1usize, 4] {
        let pool = Pool::new(width);
        let mut lane = vec![0.0f32; m * oc];
        let mut scalar = vec![0.0f32; m * oc];
        csd_gemm_i16_into_on(&pool, &mut lane, &xq, m, &p, dq);
        csd_gemm_i16_scalar_on(&pool, &mut scalar, &xq, m, &p, dq);
        assert_eq!(lane, scalar, "csd i16 lane vs scalar diverged (width {width})");
        assert!(lane.iter().any(|&v| v != 0.0), "degenerate case: all-zero output");
    }
}

// --- (d) engine-level conformance ---------------------------------------------

fn lenet_batch(seed: u64, b: usize) -> Tensor {
    let mut r = Rng::new(seed);
    let xdata: Vec<f32> = (0..b * 28 * 28).map(|_| r.f32()).collect();
    Tensor::new(vec![b, 28, 28, 1], xdata).unwrap()
}

#[test]
fn calibrated_quantized_engine_conforms_and_freezes() {
    let store = synth_store(91, ModelKind::Lenet);
    let quality = QualityConfig { phi: 4, group: 16 };
    let mut engine =
        QuantizedEngine::quantize_store(&store, quality, AssignMode::SigmaSearch).unwrap();
    let x = lenet_batch(92, 3);
    let mut scratch = Scratch::new();
    let f32_ref = engine.forward_scalar_reference(&x, &mut scratch).unwrap();
    assert!(
        engine.forward_int_scalar_reference(&x, &mut scratch).is_err(),
        "integer reference must refuse to run uncalibrated"
    );
    engine.calibrate(&x).unwrap();

    // integer serving vs the f32 oracle over the same packed layers: only
    // activation-quantization noise apart, identical predictions
    let got = engine.forward_with(&x, &mut scratch).unwrap();
    let diff = got.max_abs_diff(&f32_ref);
    assert!(diff < 5e-2, "integer datapath vs f32 oracle: {diff}");
    assert_eq!(ops::argmax_rows(&got), ops::argmax_rows(&f32_ref));

    // integer serving vs the integer scalar reference: bitwise
    let oracle = engine.forward_int_scalar_reference(&x, &mut scratch).unwrap();
    assert_eq!(got.data(), oracle.data(), "integer lane vs integer scalar oracle");

    // warm integer forwards reuse the i16 ping/pong twins: allocs freeze
    let cold = scratch.stats.allocs;
    for _ in 0..3 {
        let again = engine.forward_with(&x, &mut scratch).unwrap();
        assert_eq!(again.data(), got.data(), "warm integer pass changed the logits");
    }
    assert_eq!(scratch.stats.allocs, cold, "warm forwards allocated: {:?}", scratch.stats);
    assert_eq!(engine.ledger().act_bits, 16, "the act-width gauge must be raised");
}

#[test]
fn calibrated_csd_engine_conforms() {
    let store = synth_store(93, ModelKind::Lenet);
    let mut engine = CsdEngine::from_store(&store, CsdQuality::exact()).unwrap();
    let x = lenet_batch(94, 3);
    let mut scratch = Scratch::new();
    let f32_ref = engine.forward_scalar_reference(&x, &mut scratch).unwrap();
    engine.calibrate(&x).unwrap();
    let got = engine.forward_with(&x, &mut scratch).unwrap();
    let diff = got.max_abs_diff(&f32_ref);
    assert!(diff < 5e-2, "csd integer datapath vs f32 oracle: {diff}");
    assert_eq!(ops::argmax_rows(&got), ops::argmax_rows(&f32_ref));
    let oracle = engine.forward_int_scalar_reference(&x, &mut scratch).unwrap();
    assert_eq!(got.data(), oracle.data(), "csd integer lane vs integer scalar oracle");
    assert_eq!(engine.ledger().act_bits, 16);
}

#[test]
fn calibration_is_a_pure_fold_across_engines_and_reruns() {
    let store = synth_store(95, ModelKind::Convnet);
    let quality = QualityConfig { phi: 4, group: 16 };
    let mut r = Rng::new(96);
    let xdata: Vec<f32> = (0..2 * 32 * 32 * 3).map(|_| r.f32()).collect();
    let x = Tensor::new(vec![2, 32, 32, 3], xdata).unwrap();
    let mut a = QuantizedEngine::quantize_store(&store, quality, AssignMode::SigmaSearch).unwrap();
    let mut b = QuantizedEngine::quantize_store(&store, quality, AssignMode::SigmaSearch).unwrap();
    a.calibrate(&x).unwrap();
    b.calibrate(&x).unwrap();
    assert_eq!(a.act_plan().unwrap(), b.act_plan().unwrap(), "same batch must give one plan");
    let first = a.act_plan().unwrap().clone();
    a.calibrate(&x).unwrap();
    assert_eq!(a.act_plan().unwrap(), &first, "recalibration moved the plan");
    let fa = a.forward(&x).unwrap();
    let fb = b.forward(&x).unwrap();
    assert_eq!(fa.data(), fb.data(), "calibrated engines must serve identical logits");
}
