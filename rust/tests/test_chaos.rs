//! Chaos suite: the fault-tolerance layer under deterministic fault
//! injection ([`qsq_edge::util::faults`]).
//!
//! Every test here arms the process-global fault switchboard, so the whole
//! binary serializes on one lock and each test disarms before releasing it —
//! faults must never leak into a neighbouring test.  All servers run over
//! synthetic weight stores (`Server::start_with_store`), so the suite needs
//! no artifacts on disk.
//!
//! CI runs this binary twice — default kernel pool and
//! `PALLAS_POOL_THREADS=1` — as a determinism gate: every assertion below is
//! a pure function of the fault seed and the request sequence (fault
//! decisions are drawn on the single inference-worker thread; quarantine
//! cooldowns count route ticks, not wall time), so the outcomes must be
//! identical under both pool configurations.
//!
//! The hot-swap scenario family at the bottom drives
//! [`Server::deploy_store`] through the same switchboard: a mid-traffic
//! swap over a bursty channel, staged failures at every pipeline stage
//! (`link.burst` stuck bad, `swap.canary`, `swap.build`), and a
//! probation-window quarantine storm that rolls the old generation back.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use qsq_edge::channel::{LinkConfig, TransferError};
use qsq_edge::coordinator::server::{Client, Roster, Server, ServerConfig, AUTO_CSD_DIGITS};
use qsq_edge::coordinator::swap::{self, SwapConfig, SwapError, SwapStage};
use qsq_edge::data::{synth_store, RequestGen};
use qsq_edge::device::CsdQuality;
use qsq_edge::kernels::{Pool, Scratch};
use qsq_edge::model::meta::ModelKind;
use qsq_edge::runtime::engine::PolicySelect;
use qsq_edge::runtime::host::CsdEngine;
use qsq_edge::tensor::{ops, Tensor};
use qsq_edge::util::faults::{self, FaultPlan};
use qsq_edge::util::json::Value;

/// Arming is process-global: serialize every test and start from disarmed.
static CHAOS: Mutex<()> = Mutex::new(());

fn guard() -> std::sync::MutexGuard<'static, ()> {
    let g = CHAOS.lock().unwrap_or_else(|e| e.into_inner());
    faults::disarm();
    g
}

fn arm(spec: &str) {
    faults::arm(FaultPlan::parse(spec).unwrap());
}

/// Classify a terminal reply.
fn kind_of(reply: &Value) -> &'static str {
    if reply.get("pred").as_f64().is_some() {
        return "pred";
    }
    match reply.get("error").as_str() {
        Some("overloaded") => "overloaded",
        Some("deadline exceeded") => "deadline",
        Some("server shutting down") => "shutdown",
        Some("inference timeout") => "timeout",
        Some(_) => "engine-error",
        None => "malformed",
    }
}

fn one_image(seed: u64) -> Tensor {
    RequestGen::new(ModelKind::Lenet, seed).next().0
}

/// Serve `n` sequential requests from one client; returns reply kinds.
fn drive(port: u16, gen_seed: u64, n: usize) -> Vec<&'static str> {
    let mut c = Client::connect(&format!("127.0.0.1:{port}")).unwrap();
    let mut gen = RequestGen::new(ModelKind::Lenet, gen_seed);
    (0..n)
        .map(|i| {
            let (img, _) = gen.next();
            kind_of(&c.infer(i as u64, img.data()).unwrap())
        })
        .collect()
}

/// The roster generation a success reply was served by.
fn gen_of(reply: &Value) -> Option<u64> {
    reply.get("gen").as_f64().map(|g| g as u64)
}

/// Sequential predictions for a fixed input set (None for error replies).
fn preds_for(port: u16, gen_seed: u64, n: usize) -> Vec<Option<u64>> {
    let mut c = Client::connect(&format!("127.0.0.1:{port}")).unwrap();
    let mut gen = RequestGen::new(ModelKind::Lenet, gen_seed);
    (0..n)
        .map(|i| {
            let (img, _) = gen.next();
            let r = c.infer(i as u64, img.data()).unwrap();
            r.get("pred").as_f64().map(|p| p as u64)
        })
        .collect()
}

/// A deterministic failure fence: engine errors on host-qgemm at p=1.0 fail
/// every batch it serves until the roster quarantines it and the preference
/// order degrades singleton traffic to the exact f32 engine.
#[test]
fn quarantine_reroutes_to_a_surviving_engine() {
    let _g = guard();
    arm("seed=5;engine.error=host-qgemm:1.0");
    let cfg = ServerConfig {
        quarantine_after: 2,
        quarantine_cooldown: 100_000, // no probes inside this test
        ..Default::default()
    };
    let srv = Server::start_with_store(synth_store(41, ModelKind::Lenet), cfg).unwrap();
    let kinds = drive(srv.port, 7, 10);

    // singletons route to host-qgemm; its first two batches fail, the
    // quarantine fence drops, and every later request is served by f32
    assert_eq!(&kinds[..2], &["engine-error", "engine-error"], "{kinds:?}");
    assert!(
        kinds[2..].iter().all(|k| *k == "pred"),
        "post-quarantine requests must be served: {kinds:?}"
    );
    assert_eq!(srv.metrics.counter("engine_failures"), 2);
    assert_eq!(srv.metrics.counter("quarantines"), 1);
    assert_eq!(srv.metrics.counter("worker_panics"), 0);
    assert_eq!(srv.metrics.gauge("engine.host-qgemm.quarantined"), Some(1.0));
    assert_eq!(srv.metrics.gauge("engine.host-f32.quarantined"), Some(0.0));
    assert!(srv.metrics.counter("dispatch_host_f32") >= 8);
    srv.stop();
    faults::disarm();
}

/// Injected panics fail only the in-flight batch: the supervised worker
/// keeps the roster, quarantines the panicking engine, and — once disarmed
/// and reinstated — serves bit-identically to a fault-free server over the
/// same weights and inputs.
#[test]
fn panics_fail_one_batch_and_recovery_is_bitwise() {
    let _g = guard();
    const STORE_SEED: u64 = 42;
    const INPUT_SEED: u64 = 9;
    const N: usize = 12;

    // fault-free baseline over the same store/inputs
    let base = Server::start_with_store(
        synth_store(STORE_SEED, ModelKind::Lenet),
        ServerConfig::default(),
    )
    .unwrap();
    let baseline = preds_for(base.port, INPUT_SEED, N);
    base.stop();
    assert!(baseline.iter().all(|p| p.is_some()));

    arm("seed=6;engine.panic=host-qgemm:1.0");
    // cooldown 30 route ticks: long enough that no probe fires during the
    // 8-request armed drive (which would panic a third time), short enough
    // that the disarmed warm-up loop below reaches the probe
    let cfg = ServerConfig {
        quarantine_after: 2,
        quarantine_cooldown: 30,
        ..Default::default()
    };
    let srv = Server::start_with_store(synth_store(STORE_SEED, ModelKind::Lenet), cfg).unwrap();

    // chaos phase: the first two singleton batches panic, then quarantine
    // degrades traffic to f32 and serving continues
    let kinds = drive(srv.port, 77, 8);
    assert_eq!(&kinds[..2], &["engine-error", "engine-error"], "{kinds:?}");
    assert!(kinds[2..].iter().all(|k| *k == "pred"), "{kinds:?}");
    assert_eq!(srv.metrics.counter("worker_panics"), 2);
    assert!(srv.metrics.counter("quarantines") >= 1);

    // disarm and warm up until the probe reinstates host-qgemm
    faults::disarm();
    let mut c = Client::connect(&format!("127.0.0.1:{}", srv.port)).unwrap();
    let img = one_image(1234);
    let mut reinstated = false;
    for i in 0..50 {
        let r = c.infer(1000 + i, img.data()).unwrap();
        assert_eq!(kind_of(&r), "pred", "disarmed serving must be clean");
        if srv.metrics.gauge("engine.host-qgemm.quarantined") == Some(0.0) {
            reinstated = true;
            break;
        }
    }
    assert!(reinstated, "cooldown probe must reinstate the engine");

    // post-chaos: bitwise-identical predictions to the fault-free baseline
    let recovered = preds_for(srv.port, INPUT_SEED, N);
    assert_eq!(recovered, baseline, "post-chaos forwards must match fault-free");
    srv.stop();
    faults::disarm();
}

/// Bounded admission: with the worker wedged by injected pop stalls, a tiny
/// queue fills and pushes shed with `overloaded` + a positive
/// `retry_after_ms`, while accepted requests still complete.
#[test]
fn overload_sheds_with_retry_after_hint() {
    let _g = guard();
    arm("seed=8;queue.stall=1.0:40");
    let cfg = ServerConfig {
        batch: 4,
        queue_cap: 4,
        max_delay: Duration::from_millis(1),
        ..Default::default()
    };
    let srv = Server::start_with_store(synth_store(43, ModelKind::Lenet), cfg).unwrap();
    let port = srv.port;

    let threads: Vec<_> = (0..12)
        .map(|t| {
            std::thread::spawn(move || {
                let mut c = Client::connect(&format!("127.0.0.1:{port}")).unwrap();
                let mut gen = RequestGen::new(ModelKind::Lenet, 100 + t);
                let (mut preds, mut shed) = (0u64, 0u64);
                for i in 0..6 {
                    let (img, _) = gen.next();
                    let r = c.infer(t * 100 + i, img.data()).unwrap();
                    match kind_of(&r) {
                        "pred" => preds += 1,
                        "overloaded" => {
                            let hint = r.get("retry_after_ms").as_f64().unwrap();
                            assert!(hint >= 1.0, "retry hint must be positive: {hint}");
                            shed += 1;
                        }
                        other => panic!("unexpected reply kind {other}: {}", r.to_json()),
                    }
                }
                (preds, shed)
            })
        })
        .collect();
    let (mut preds, mut shed) = (0, 0);
    for t in threads {
        let (p, s) = t.join().unwrap();
        preds += p;
        shed += s;
    }
    assert_eq!(preds + shed, 72, "every request got a terminal reply");
    assert!(shed > 0, "12 clients into a cap-4 queue must shed");
    assert!(preds > 0, "admitted requests must still be served");
    assert_eq!(srv.metrics.counter("shed_overload"), shed);
    assert_eq!(srv.metrics.counter("requests"), preds);
    srv.stop();
    faults::disarm();
}

/// Deadline shedding at the server level: jobs that sat queued past the
/// deadline while the worker was wedged get a prompt `deadline exceeded`
/// reply instead of burning a kernel slot.
#[test]
fn stale_jobs_are_shed_at_the_deadline() {
    let _g = guard();
    arm("seed=9;queue.stall=1.0:150");
    let cfg = ServerConfig {
        deadline: Duration::from_millis(50),
        max_delay: Duration::from_millis(2),
        ..Default::default()
    };
    let srv = Server::start_with_store(synth_store(44, ModelKind::Lenet), cfg).unwrap();
    let port = srv.port;

    let threads: Vec<_> = (0..4)
        .map(|t| {
            std::thread::spawn(move || {
                let kinds = drive(port, 200 + t, 3);
                assert!(
                    kinds.iter().all(|k| *k == "pred" || *k == "deadline"),
                    "only served or deadline-shed: {kinds:?}"
                );
                kinds.iter().filter(|k| **k == "deadline").count() as u64
            })
        })
        .collect();
    let shed: u64 = threads.into_iter().map(|t| t.join().unwrap()).sum();
    assert!(shed > 0, "a 150ms-stalled worker must shed 50ms-deadline jobs");
    assert_eq!(srv.metrics.counter("shed_deadline"), shed);
    srv.stop();
    faults::disarm();
}

/// Graceful shutdown: requests still queued when `stop()` lands get an
/// explicit `server shutting down` reply promptly — no client ever waits
/// out its reply timeout against a dropped sender.
#[test]
fn shutdown_replies_to_queued_jobs_promptly() {
    let _g = guard();
    arm("seed=10;queue.stall=1.0:300");
    let cfg = ServerConfig {
        max_delay: Duration::from_millis(2),
        ..Default::default()
    };
    let srv = Server::start_with_store(synth_store(45, ModelKind::Lenet), cfg).unwrap();
    let port = srv.port;

    let clients: Vec<_> = (0..5)
        .map(|t| {
            std::thread::spawn(move || {
                let mut c = Client::connect(&format!("127.0.0.1:{port}")).unwrap();
                let img = one_image(300 + t);
                let t0 = Instant::now();
                let r = c.infer(t, img.data()).unwrap();
                (kind_of(&r), t0.elapsed())
            })
        })
        .collect();
    // let the requests reach the queue (the worker is stalled), then stop
    std::thread::sleep(Duration::from_millis(100));
    srv.stop();

    let mut shutdown_replies = 0;
    for c in clients {
        let (kind, waited) = c.join().unwrap();
        assert!(
            kind == "pred" || kind == "shutdown",
            "terminal reply required, got {kind}"
        );
        assert!(
            waited < Duration::from_secs(5),
            "reply after stop() took {waited:?} — the old path hung 30s"
        );
        if kind == "shutdown" {
            shutdown_replies += 1;
        }
    }
    assert!(shutdown_replies > 0, "the stalled worker left a backlog to drain");
    faults::disarm();
}

/// The full storm — overload, injected errors, panics, latency spikes, and
/// pop stalls at once.  Every request gets a terminal reply within the
/// configured reply window, the shed/quarantine metrics move, and after
/// disarming the same server serves bit-identically to a fault-free run.
#[test]
fn mixed_chaos_yields_terminal_replies_then_bitwise_recovery() {
    let _g = guard();
    const STORE_SEED: u64 = 46;
    const INPUT_SEED: u64 = 11;
    const N: usize = 8;

    let base = Server::start_with_store(
        synth_store(STORE_SEED, ModelKind::Lenet),
        ServerConfig::default(),
    )
    .unwrap();
    let baseline = preds_for(base.port, INPUT_SEED, N);
    base.stop();

    arm(
        "seed=12;engine.error=*:0.10;engine.panic=*:0.05;engine.delay=*:0.10:5;\
         queue.stall=0.3:10",
    );
    let cfg = ServerConfig {
        batch: 4,
        queue_cap: 8,
        max_delay: Duration::from_millis(2),
        deadline: Duration::from_millis(300),
        quarantine_after: 2,
        quarantine_cooldown: 8,
        ..Default::default()
    };
    let reply_window = cfg.reply_timeout() + Duration::from_secs(2);
    let srv = Server::start_with_store(synth_store(STORE_SEED, ModelKind::Lenet), cfg).unwrap();
    let port = srv.port;

    let threads: Vec<_> = (0..8)
        .map(|t| {
            std::thread::spawn(move || {
                let mut c = Client::connect(&format!("127.0.0.1:{port}")).unwrap();
                let mut gen = RequestGen::new(ModelKind::Lenet, 400 + t);
                let mut counts = std::collections::BTreeMap::new();
                for i in 0..20u64 {
                    let (img, _) = gen.next();
                    let t0 = Instant::now();
                    let r = c.infer(t * 1000 + i, img.data()).unwrap();
                    assert!(
                        t0.elapsed() < reply_window,
                        "reply exceeded the bounded window: {:?}",
                        t0.elapsed()
                    );
                    *counts.entry(kind_of(&r)).or_insert(0u64) += 1;
                }
                counts
            })
        })
        .collect();
    let mut total = std::collections::BTreeMap::new();
    for t in threads {
        for (k, v) in t.join().unwrap() {
            *total.entry(k).or_insert(0) += v;
        }
    }
    assert!(!total.contains_key("malformed"), "{total:?}");
    assert_eq!(total.values().sum::<u64>(), 160, "all requests terminal: {total:?}");
    assert!(total.get("pred").copied().unwrap_or(0) > 0, "{total:?}");
    let m = &srv.metrics;
    assert!(
        m.counter("engine_failures") + m.counter("worker_panics") > 0,
        "the storm must have hit some batches"
    );

    // calm: disarm, then warm up until host-qgemm — the engine singleton
    // traffic routes to, i.e. the one the recovery comparison exercises —
    // is reinstated (engines that win no routes are never probed, by
    // design: quarantine only gates engines traffic would actually reach)
    faults::disarm();
    let mut c = Client::connect(&format!("127.0.0.1:{port}")).unwrap();
    let img = one_image(5000);
    let mut calm = false;
    for i in 0..100 {
        let r = c.infer(9000 + i, img.data()).unwrap();
        assert_eq!(kind_of(&r), "pred", "disarmed serving must be clean");
        if m.gauge("engine.host-qgemm.quarantined") != Some(1.0) {
            calm = true;
            break;
        }
    }
    assert!(calm, "the serving engine must reinstate after the storm");
    let recovered = preds_for(port, INPUT_SEED, N);
    assert_eq!(recovered, baseline, "post-chaos forwards must match fault-free");
    srv.stop();
    faults::disarm();
}

/// The CI determinism gate's foundation: with a fixed seed, the exact
/// sequence of (routed engine, outcome) over a fixed request stream is
/// reproducible — re-arming the same plan replays the same decisions, and
/// nothing in the path depends on pool parallelism or wall time.
#[test]
fn fault_stream_is_deterministic_for_a_fixed_seed() {
    let _g = guard();
    let spec = "seed=1234;engine.error=*:0.35;engine.delay=*:0.1:1";

    let run = || {
        arm(spec);
        let cfg = ServerConfig {
            policy: PolicySelect::EnergyBudget,
            quarantine_after: 2,
            quarantine_cooldown: 5,
            ..Default::default()
        };
        let roster = Roster::build(None, synth_store(55, ModelKind::Lenet), &cfg).unwrap();
        let mut scratch = Scratch::new();
        let mut pix = qsq_edge::util::rng::Rng::new(99);
        let mut seq = Vec::new();
        for i in 0..120usize {
            let n = 1 + i % 4; // fixed batch-size pattern
            let data: Vec<f32> = (0..n * 28 * 28).map(|_| pix.f32()).collect();
            let x = Tensor::new(vec![n, 28, 28, 1], data).unwrap();
            let idx = roster.route(n);
            let ok = roster.forward(idx, &x, &mut scratch).is_ok();
            if ok {
                roster.note_ok(idx);
            } else {
                roster.note_failure(idx);
            }
            seq.push((idx, ok));
        }
        let events = roster.quarantine_events();
        faults::disarm();
        (seq, events)
    };

    let (seq_a, events_a) = run();
    let (seq_b, events_b) = run();
    assert_eq!(seq_a, seq_b, "same seed, same request stream → same decisions");
    assert_eq!(events_a, events_b);
    assert!(
        seq_a.iter().filter(|(_, ok)| !ok).count() >= 10,
        "p=0.35 over 120 forwards must inject a healthy error count"
    );
    assert!(events_a >= 1, "consecutive errors must have quarantined at least once");
    assert!(
        seq_a.iter().any(|(_, ok)| *ok),
        "most forwards still succeed under p=0.35"
    );
}

/// Arming is explicit and disarming is total: after `disarm`, the hooks are
/// no-ops again and a freshly built roster carries no injector wrappers.
#[test]
fn disarm_restores_clean_serving() {
    let _g = guard();
    arm("seed=3;engine.error=*:1.0");
    assert!(faults::armed());
    assert!(faults::engine_action("host-f32").is_some());
    faults::disarm();
    assert!(!faults::armed());
    assert_eq!(faults::engine_action("host-f32"), None);
    assert_eq!(faults::queue_stall(), None);

    // a server built disarmed serves every request cleanly
    let srv = Server::start_with_store(
        synth_store(47, ModelKind::Lenet),
        ServerConfig::default(),
    )
    .unwrap();
    let kinds = drive(srv.port, 13, 5);
    assert!(kinds.iter().all(|k| *k == "pred"), "{kinds:?}");
    assert_eq!(srv.metrics.counter("engine_failures"), 0);
    assert_eq!(srv.metrics.counter("worker_panics"), 0);
    srv.stop();
}

// --- hot model swap under chaos ---------------------------------------------

/// The headline swap scenario: continuous traffic from four clients while a
/// new model generation ships over a bursty channel mid-stream.  Zero
/// requests are dropped or left hanging, the generation stamp in the replies
/// advances 1 → 2, and post-swap predictions match a reference staging of
/// the same store bit-for-bit (at `batch: 4` even singletons clear the
/// quarter-full crossover, so batch-fill routes everything to the
/// artifact-class f32 engine — the compare runs against
/// `staged.engines[2]`).
#[test]
fn hot_swap_mid_traffic_over_bursty_channel() {
    let _g = guard();
    const STORE_A: u64 = 61;
    const STORE_B: u64 = 62;
    // armed before start: the boot roster gets (pass-through) injector
    // wrappers and the deploy link gets the Gilbert–Elliott burst profile
    arm("seed=21;link.burst=0.001:0.05:0.01");
    let cfg = ServerConfig {
        batch: 4,
        max_delay: Duration::from_millis(2),
        probation_batches: 4,
        ..Default::default()
    };
    let srv = Server::start_with_store(synth_store(STORE_A, ModelKind::Lenet), cfg).unwrap();
    let port = srv.port;

    let stop = Arc::new(AtomicBool::new(false));
    let threads: Vec<_> = (0..4u64)
        .map(|t| {
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut c = Client::connect(&format!("127.0.0.1:{port}")).unwrap();
                let mut gen = RequestGen::new(ModelKind::Lenet, 500 + t);
                let mut n = 0u64;
                let mut gens = std::collections::BTreeSet::new();
                while !stop.load(Ordering::Relaxed) {
                    let (img, _) = gen.next();
                    let r = c.infer(t * 10_000 + n, img.data()).unwrap();
                    assert_eq!(
                        kind_of(&r),
                        "pred",
                        "no request may drop during the swap: {}",
                        r.to_json()
                    );
                    gens.insert(gen_of(&r).expect("success replies carry gen"));
                    n += 1;
                }
                (n, gens)
            })
        })
        .collect();

    // let traffic establish on generation 1, then deploy mid-stream
    std::thread::sleep(Duration::from_millis(50));
    let scfg = SwapConfig {
        link: LinkConfig { max_retries: 64, ..Default::default() },
        seed: 33,
        ..Default::default()
    };
    let store_b = synth_store(STORE_B, ModelKind::Lenet);
    let rep = srv.deploy_store(&store_b, &scfg).unwrap();
    assert_eq!(rep.generation, 2);
    assert!(
        rep.transfer.retransmissions > 0,
        "the burst profile must have forced ARQ retransmissions"
    );
    assert_eq!(rep.transfer.frames_delivered, rep.transfer.frames);
    assert_eq!(rep.canary.len(), 3, "every staged engine was canaried");

    // let the new generation serve under load, then stop traffic
    std::thread::sleep(Duration::from_millis(50));
    stop.store(true, Ordering::Relaxed);
    let mut total = 0u64;
    let mut gens = std::collections::BTreeSet::new();
    for t in threads {
        let (n, g) = t.join().unwrap();
        total += n;
        gens.extend(g);
    }
    assert!(total > 0, "traffic must actually have flowed");
    assert!(
        gens.contains(&1) && gens.contains(&2),
        "both generations must have served: {gens:?}"
    );

    // post-swap logits must bitwise-match the new store: an independent
    // staging of the same store over the same (seeded) channel builds
    // bitwise-identical engines, so its predictions are the ground truth
    let staged = swap::stage(&store_b, &scfg).unwrap();
    let mut scratch = Scratch::new();
    let mut c = Client::connect(&format!("127.0.0.1:{port}")).unwrap();
    let mut reqs = RequestGen::new(ModelKind::Lenet, 777);
    for i in 0..12u64 {
        let (img, _) = reqs.next();
        let r = c.infer(90_000 + i, img.data()).unwrap();
        assert_eq!(kind_of(&r), "pred");
        assert_eq!(gen_of(&r), Some(2), "all post-swap traffic is generation 2");
        let x = Tensor::new(vec![1, 28, 28, 1], img.data().to_vec()).unwrap();
        let logits = staged.engines[2].forward_with(&x, &mut scratch).unwrap();
        let want = ops::argmax_rows(&logits)[0] as f64;
        assert_eq!(r.get("pred").as_f64(), Some(want), "request {i} diverged");
    }

    let m = &srv.metrics;
    assert_eq!(m.counter("swap.attempts"), 1);
    assert_eq!(m.counter("swap.installs"), 1);
    assert_eq!(m.counter("swap.rollbacks"), 0);
    assert_eq!(m.counter("swap.failed"), 0);
    assert_eq!(m.gauge("swap.generation"), Some(2.0));
    assert_eq!(m.counter("shed_overload"), 0, "admission stayed bounded and clean");
    srv.stop();
    faults::disarm();
}

/// Every staging failure mode leaves the old generation serving untouched:
/// ARQ exhaustion on a stuck-bad channel (deterministic for a fixed seed —
/// satellite of the CI determinism gate), an injected canary rejection, and
/// an injected engine-build failure.  Each is surfaced as a typed
/// [`SwapError`] naming the stage, with the partial transfer report
/// reachable under the transfer failure.
#[test]
fn failed_deploy_stages_leave_the_old_generation_serving() {
    let _g = guard();
    const STORE_A: u64 = 63;
    const STORE_B: u64 = 64;
    // built disarmed: the serving path itself is fault-free throughout
    let srv = Server::start_with_store(
        synth_store(STORE_A, ModelKind::Lenet),
        ServerConfig::default(),
    )
    .unwrap();
    let baseline = preds_for(srv.port, 17, 6);
    assert!(baseline.iter().all(|p| p.is_some()));
    let store_b = synth_store(STORE_B, ModelKind::Lenet);

    // 1. transfer exhaustion: Gilbert–Elliott stuck in the bad state
    // corrupts every frame, so frame 0 exhausts its retries — identically
    // for any fixed seed and pool configuration
    arm("seed=24;link.burst=1.0:0.0:0.5");
    let scfg = SwapConfig {
        link: LinkConfig { max_retries: 3, ..Default::default() },
        seed: 24,
        ..Default::default()
    };
    let err = srv.deploy_store(&store_b, &scfg).unwrap_err();
    let se = err.downcast_ref::<SwapError>().expect("typed stage error");
    assert_eq!(se.stage, SwapStage::Transfer);
    let te = se
        .source
        .downcast_ref::<TransferError>()
        .expect("the partial transfer report survives the stage wrapper");
    assert_eq!(te.frame, 0, "the first frame already exhausts");
    assert_eq!(te.partial.frames_delivered, 0, "stuck-bad: nothing lands");
    assert_eq!(te.partial.retransmissions, 4, "max_retries 3 → exactly 4 sends");

    // 2. canary divergence (injected at certainty — no RNG draw, so the
    // worker's fault stream is untouched)
    arm("seed=25;swap.canary=1.0");
    let err = srv.deploy_store(&store_b, &SwapConfig::default()).unwrap_err();
    assert_eq!(err.downcast_ref::<SwapError>().unwrap().stage, SwapStage::Canary);

    // 3. engine-build failure (injected)
    arm("seed=26;swap.build=1.0");
    let err = srv.deploy_store(&store_b, &SwapConfig::default()).unwrap_err();
    assert_eq!(err.downcast_ref::<SwapError>().unwrap().stage, SwapStage::Build);

    faults::disarm();
    let m = &srv.metrics;
    assert_eq!(m.counter("swap.attempts"), 3);
    assert_eq!(m.counter("swap.failed"), 3);
    assert_eq!(m.counter("swap.fail.transfer"), 1);
    assert_eq!(m.counter("swap.canary_rejects"), 1);
    assert_eq!(m.counter("swap.fail.build"), 1);
    assert_eq!(m.counter("swap.installs"), 0);
    assert_eq!(m.counter("swap.rollbacks"), 0);
    assert_eq!(m.gauge("swap.generation"), Some(1.0), "generation never moved");
    // the old generation answers, bit-identically to before the failed deploys
    assert_eq!(preds_for(srv.port, 17, 6), baseline);
    srv.stop();
    faults::disarm();
}

/// A swap that *installs* cleanly but collapses under traffic rolls back
/// automatically: the staged generation passes its canary on raw engines,
/// the install wraps it in (armed) fault injectors, every batch it serves
/// errors, and the first quarantine event inside the probation window
/// reinstates the displaced generation — which then serves bit-identically
/// to the pre-swap baseline.
#[test]
fn quarantine_storm_during_probation_rolls_back() {
    let _g = guard();
    const STORE_A: u64 = 65;
    const STORE_B: u64 = 66;
    let cfg = ServerConfig {
        quarantine_after: 2,
        probation_batches: 16,
        rollback_quarantines: 1,
        ..Default::default()
    };
    // built DISARMED: the boot generation carries no injector wrappers, so
    // the storm below only ever hits the swapped-in generation
    let srv = Server::start_with_store(synth_store(STORE_A, ModelKind::Lenet), cfg).unwrap();
    let baseline = preds_for(srv.port, 19, 6);
    assert!(baseline.iter().all(|p| p.is_some()));

    // arm engine errors, then deploy: staging forwards on the raw engines
    // (the canary judges the model, not the chaos harness), but the install
    // wraps the new generation — which then fails every batch it serves
    arm("seed=27;engine.error=*:1.0");
    let rep = srv
        .deploy_store(&synth_store(STORE_B, ModelKind::Lenet), &SwapConfig::default())
        .unwrap();
    assert_eq!(rep.generation, 2);
    assert_eq!(srv.metrics.gauge("swap.generation"), Some(2.0));

    // two singleton batches fail (quarantine_after = 2) → quarantine event →
    // probation storm → automatic rollback; everything after is served by
    // the displaced generation
    let kinds = drive(srv.port, 600, 8);
    assert_eq!(&kinds[..2], &["engine-error", "engine-error"], "{kinds:?}");
    assert!(
        kinds[2..].iter().all(|k| *k == "pred"),
        "rolled-back serving must be clean: {kinds:?}"
    );
    let m = &srv.metrics;
    assert_eq!(m.counter("swap.installs"), 1);
    assert_eq!(m.counter("swap.rollbacks"), 1);
    assert_eq!(m.gauge("swap.generation"), Some(1.0), "back on generation 1");
    assert!(m.counter("quarantines") >= 1);

    faults::disarm();
    assert_eq!(
        preds_for(srv.port, 19, 6),
        baseline,
        "the rolled-back generation answers bit-identically"
    );
    srv.stop();
    faults::disarm();
}

/// Lane-ized serving as a pure function of the request stream: the same
/// fixed-seed traffic with a hot swap mid-stream yields an identical
/// (generation, prediction, outcome) sequence across repeated runs and
/// across both band-leasing modes (sticky-pinned and re-dealt) — pinning
/// only moves bands between workers — and every prediction matches the
/// *scalar* plane-sum reference forward of the generation that served it.
/// Batch 16 under the energy policy routes every singleton to the CSD
/// engine, so the whole stream exercises the lane-ized digit-plane sums;
/// CI re-runs this binary under `PALLAS_POOL_THREADS=1`, and every
/// assertion below must hold unchanged there.
#[test]
fn lane_swap_stream_is_pin_invariant_and_matches_scalar_reference() {
    let _g = guard();
    const STORE_A: u64 = 71;
    const STORE_B: u64 = 72;
    const SWAP_AT: u64 = 12;
    const TOTAL: u64 = 24;

    let run = |pinned: bool| {
        Pool::global().set_pinned(pinned);
        arm("seed=29;link.burst=0.001:0.05:0.01");
        let cfg = ServerConfig {
            policy: PolicySelect::EnergyBudget,
            batch: 16,
            max_delay: Duration::from_millis(1),
            probation_batches: 2,
            ..Default::default()
        };
        let srv = Server::start_with_store(synth_store(STORE_A, ModelKind::Lenet), cfg).unwrap();
        let mut c = Client::connect(&format!("127.0.0.1:{}", srv.port)).unwrap();
        let mut gen = RequestGen::new(ModelKind::Lenet, 880);
        let scfg = SwapConfig {
            link: LinkConfig { max_retries: 64, ..Default::default() },
            seed: 35,
            ..Default::default()
        };
        let mut stream = Vec::new();
        for i in 0..TOTAL {
            if i == SWAP_AT {
                let rep = srv
                    .deploy_store(&synth_store(STORE_B, ModelKind::Lenet), &scfg)
                    .unwrap();
                assert_eq!(rep.generation, 2);
            }
            let (img, _) = gen.next();
            let r = c.infer(i, img.data()).unwrap();
            stream.push((gen_of(&r), r.get("pred").as_f64().map(|p| p as u64), kind_of(&r)));
        }
        assert!(
            srv.metrics.counter("dispatch_host_csd") >= TOTAL,
            "energy policy must route the singleton stream to the CSD engine"
        );
        faults::disarm();
        srv.stop();
        stream
    };

    let first = run(true);
    let again = run(true);
    assert_eq!(first, again, "fixed seed must reproduce the exact stream");
    let redealt = run(false);
    Pool::global().set_pinned(true); // restore the default leasing mode
    assert_eq!(first, redealt, "re-dealt leasing must not change any outcome");

    // every reply succeeded and the generation flips exactly at the swap
    for (i, (g, p, k)) in first.iter().enumerate() {
        assert_eq!(*k, "pred", "request {i}: {first:?}");
        assert!(p.is_some(), "request {i}");
        let want_gen = if (i as u64) < SWAP_AT { 1 } else { 2 };
        assert_eq!(*g, Some(want_gen), "request {i} generation");
    }

    // scalar ground truth: per generation, a CSD engine at the roster's
    // digit budget forwarded through the retained scalar plane-sum oracles
    // — a lane-ization bug that moves any logit across an argmax boundary
    // diverges here
    let quality = CsdQuality::new(AUTO_CSD_DIGITS);
    let engines = [
        CsdEngine::from_store(&synth_store(STORE_A, ModelKind::Lenet), quality).unwrap(),
        CsdEngine::from_store(&synth_store(STORE_B, ModelKind::Lenet), quality).unwrap(),
    ];
    let mut scratch = Scratch::new();
    let mut gen = RequestGen::new(ModelKind::Lenet, 880);
    for (i, (_, p, _)) in first.iter().enumerate() {
        let (img, _) = gen.next();
        let x = Tensor::new(vec![1, 28, 28, 1], img.data().to_vec()).unwrap();
        let e = &engines[usize::from(i as u64 >= SWAP_AT)];
        let logits = e.forward_scalar_reference(&x, &mut scratch).unwrap();
        assert_eq!(
            p.unwrap(),
            ops::argmax_rows(&logits)[0] as u64,
            "request {i} diverged from the scalar plane-sum baseline"
        );
    }
}
