//! Cross-language parity: the rust quantizer must reproduce the python
//! quantizer (`python/compile/qsq_lib.py`) on the vectors written to
//! `artifacts/parity/` by `make artifacts`.
//!
//! Codes are compared with a small mismatch allowance (threshold-boundary
//! elements can flip under f32-vs-f64 accumulation differences); decoded
//! weights must agree to 1e-3 absolute.

use std::path::PathBuf;

use qsq_edge::quant::qsq::{quantize, AssignMode};
use qsq_edge::util::{json, npy};

fn artifacts() -> PathBuf {
    std::env::var("QSQ_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| PathBuf::from("artifacts"))
}

fn parity_dir() -> Option<PathBuf> {
    let d = artifacts().join("parity");
    d.join("index.json").exists().then_some(d)
}

#[test]
fn quantizer_matches_python_on_parity_vectors() {
    let Some(dir) = parity_dir() else {
        eprintln!("skipping: no artifacts/parity (run `make artifacts`)");
        return;
    };
    let w = npy::read(dir.join("w.npy")).unwrap();
    let wdata = w.to_f32().unwrap();
    let index: json::Value =
        json::parse(&std::fs::read_to_string(dir.join("index.json")).unwrap()).unwrap();

    let mut cases = 0;
    for case in index.as_arr().unwrap() {
        let tag = case.get("tag").as_str().unwrap();
        let phi = case.get("phi").as_usize().unwrap() as u32;
        let group = case.get("group").as_usize().unwrap();
        let mode = match case.get("mode").as_str().unwrap() {
            "sigma-search" => AssignMode::SigmaSearch,
            "nearest" => AssignMode::Nearest,
            "nearest-opt" => AssignMode::NearestOpt,
            m => panic!("unknown mode {m}"),
        };
        let qt = quantize(&wdata, &w.shape, group, phi, mode).unwrap();

        let py_codes = npy::read(dir.join(format!("codes_{tag}.npy"))).unwrap().to_i8().unwrap();
        let py_scalars =
            npy::read(dir.join(format!("scalars_{tag}.npy"))).unwrap().to_f32().unwrap();
        let py_decoded =
            npy::read(dir.join(format!("decoded_{tag}.npy"))).unwrap().to_f32().unwrap();

        // scalars: tight tolerance
        assert_eq!(qt.scalars.len(), py_scalars.len(), "{tag}: scalar count");
        for (i, (&a, &b)) in qt.scalars.iter().zip(&py_scalars).enumerate() {
            assert!(
                (a - b).abs() <= 1e-5 * (1.0 + b.abs()),
                "{tag}: scalar[{i}] {a} vs {b}"
            );
        }
        // codes: allow <=1% boundary flips
        let mismatches = qt
            .codes
            .iter()
            .zip(&py_codes)
            .filter(|(a, b)| a.0 as i8 != **b)
            .count();
        assert!(
            mismatches <= qt.codes.len() / 100 + 1,
            "{tag}: {mismatches}/{} code mismatches",
            qt.codes.len()
        );
        // decoded weights: close everywhere
        let dec = qt.decode();
        for (i, (&a, &b)) in dec.iter().zip(&py_decoded).enumerate() {
            assert!((a - b).abs() <= 2e-3, "{tag}: decoded[{i}] {a} vs {b}");
        }
        // sigma-search picks the same or equally good thresholds
        if let Some(py_err) = case.get("error").as_f64() {
            let err = qt.error(&wdata);
            assert!(
                (err - py_err).abs() <= 0.02 * (1.0 + py_err),
                "{tag}: eq.-5 error {err} vs python {py_err}"
            );
        }
        cases += 1;
    }
    assert!(cases >= 27, "expected >=27 parity cases, ran {cases}");
}

#[test]
fn gamma_delta_search_agrees_with_python() {
    let Some(dir) = parity_dir() else {
        eprintln!("skipping: no artifacts/parity");
        return;
    };
    let w = npy::read(dir.join("w.npy")).unwrap();
    let wdata = w.to_f32().unwrap();
    let index: json::Value =
        json::parse(&std::fs::read_to_string(dir.join("index.json")).unwrap()).unwrap();
    for case in index.as_arr().unwrap() {
        if case.get("mode").as_str() != Some("sigma-search") {
            continue;
        }
        let phi = case.get("phi").as_usize().unwrap() as u32;
        let group = case.get("group").as_usize().unwrap();
        let qt = quantize(&wdata, &w.shape, group, phi, AssignMode::SigmaSearch).unwrap();
        let (pg, pd) = (
            case.get("gamma").as_f64().unwrap(),
            case.get("delta").as_f64().unwrap(),
        );
        // grids are identical; equal-error ties may pick different cells, so
        // compare achieved error rather than raw (gamma, delta) when they
        // disagree
        if (qt.gamma - pg).abs() > 1e-9 || (qt.delta - pd).abs() > 1e-9 {
            let err = qt.error(&wdata);
            let py_err = case.get("error").as_f64().unwrap();
            assert!(
                err <= py_err * 1.02 + 1e-9,
                "phi={phi} g={group}: rust ({},{}) err {err} worse than python ({pg},{pd}) err {py_err}",
                qt.gamma,
                qt.delta
            );
        }
    }
}
