//! Failure-injection / fuzz-ish robustness: hostile bytes must produce
//! errors, never panics, across every parsing surface (container, frames,
//! npy, json, server requests).

use qsq_edge::channel::frame::Frame;
use qsq_edge::codec::{decode_model, encode_model, EncodedModel, EncodedTensor};
use qsq_edge::quant::qsq::{quantize, AssignMode};
use qsq_edge::util::prop::gen_weights;
use qsq_edge::util::rng::Rng;
use qsq_edge::util::{json, npy};

fn sample_container(seed: u64) -> Vec<u8> {
    let mut r = Rng::new(seed);
    let w = gen_weights(&mut r, 48 * 8, 0.1);
    let model = EncodedModel {
        tensors: vec![EncodedTensor {
            name: "t".into(),
            tensor: quantize(&w, &[48, 8], 8, 4, AssignMode::Nearest).unwrap(),
        }],
    };
    encode_model(&model).unwrap()
}

#[test]
fn container_survives_random_mutations() {
    let bytes = sample_container(1);
    let mut r = Rng::new(99);
    let mut detected = 0;
    for _ in 0..300 {
        let mut bad = bytes.clone();
        // 1-4 random byte mutations
        for _ in 0..=r.below(3) {
            let i = r.below(bad.len() as u64) as usize;
            bad[i] ^= (1 + r.below(255)) as u8;
        }
        // must never panic; corruption must be detected (total CRC covers all)
        if decode_model(&bad).is_err() {
            detected += 1;
        }
    }
    assert!(detected >= 299, "only {detected}/300 mutations detected");
}

#[test]
fn container_survives_random_truncation() {
    let bytes = sample_container(2);
    let mut r = Rng::new(7);
    for _ in 0..100 {
        let n = r.below(bytes.len() as u64) as usize;
        let _ = decode_model(&bytes[..n]); // must not panic
    }
}

#[test]
fn container_survives_pure_garbage() {
    let mut r = Rng::new(3);
    for len in [0usize, 1, 7, 64, 1024] {
        let garbage: Vec<u8> = (0..len).map(|_| r.below(256) as u8).collect();
        assert!(decode_model(&garbage).is_err());
    }
}

#[test]
fn frame_parser_never_panics() {
    let mut r = Rng::new(5);
    for _ in 0..500 {
        let len = r.below(64) as usize;
        let garbage: Vec<u8> = (0..len).map(|_| r.below(256) as u8).collect();
        let _ = Frame::from_bytes(&garbage);
    }
}

#[test]
fn npy_parser_never_panics() {
    let mut r = Rng::new(6);
    // random garbage
    for _ in 0..200 {
        let len = r.below(256) as usize;
        let garbage: Vec<u8> = (0..len).map(|_| r.below(256) as u8).collect();
        let _ = npy::parse(&garbage);
    }
    // valid magic + garbage header
    for _ in 0..200 {
        let mut data = b"\x93NUMPY\x01\x00".to_vec();
        let len = r.below(128) as usize;
        data.extend((0..len).map(|_| r.below(256) as u8));
        let _ = npy::parse(&data);
    }
}

#[test]
fn json_parser_never_panics() {
    let mut r = Rng::new(8);
    let charset: Vec<char> = "{}[]\",:0123456789.eE+-truefalsnl \\u00".chars().collect();
    for _ in 0..2000 {
        let len = r.below(48) as usize;
        let s: String = (0..len)
            .map(|_| charset[r.below(charset.len() as u64) as usize])
            .collect();
        let _ = json::parse(&s);
    }
}

#[test]
fn json_roundtrip_fuzz() {
    // random valid values must roundtrip exactly
    let mut r = Rng::new(9);
    fn gen(r: &mut Rng, depth: u32) -> json::Value {
        match if depth > 2 { r.below(4) } else { r.below(6) } {
            0 => json::Value::Null,
            1 => json::Value::Bool(r.chance(0.5)),
            2 => json::num((r.normal() * 100.0).round()),
            3 => json::s(&format!("s{}", r.below(1000))),
            4 => json::Value::Arr((0..r.below(4)).map(|_| gen(r, depth + 1)).collect()),
            _ => json::obj(
                (0..r.below(4))
                    .map(|i| (format!("k{i}"), gen(r, depth + 1)))
                    .collect::<Vec<_>>()
                    .iter()
                    .map(|(k, v)| (k.as_str(), v.clone()))
                    .collect(),
            ),
        }
    }
    for _ in 0..300 {
        let v = gen(&mut r, 0);
        let text = v.to_json();
        assert_eq!(json::parse(&text).unwrap(), v, "roundtrip failed for {text}");
    }
}

#[test]
fn quantizer_handles_pathological_inputs() {
    for w in [
        vec![0.0f32; 32],
        vec![f32::MIN_POSITIVE; 32],
        vec![1e30f32; 32],
        vec![-1e-30f32; 32],
        {
            let mut v = vec![0.0f32; 32];
            v[0] = 1.0;
            v
        },
    ] {
        for mode in [AssignMode::Nearest, AssignMode::SigmaSearch, AssignMode::NearestOpt] {
            let qt = quantize(&w, &[32, 1], 8, 4, mode).unwrap();
            for d in qt.decode() {
                assert!(d.is_finite(), "non-finite decode for mode {mode:?}");
            }
        }
    }
}
