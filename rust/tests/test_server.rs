//! Serving-path integration: TCP server with dynamic batching over the PJRT
//! runtime, exercised by concurrent clients.

use std::path::PathBuf;
use std::time::Duration;

use qsq_edge::coordinator::server::{Client, Server, ServerConfig};
use qsq_edge::data::RequestGen;
use qsq_edge::model::meta::ModelKind;

fn artifacts() -> Option<PathBuf> {
    let d = std::env::var("QSQ_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"));
    d.join("manifest.json").exists().then_some(d)
}

macro_rules! need_artifacts {
    () => {
        match artifacts() {
            Some(d) => d,
            None => {
                eprintln!("skipping: no artifacts (run `make artifacts`)");
                return;
            }
        }
    };
}

#[test]
fn serves_single_request() {
    let dir = need_artifacts!();
    let srv = Server::start(dir, ServerConfig::default()).unwrap();
    let mut c = Client::connect(&format!("127.0.0.1:{}", srv.port)).unwrap();
    let mut gen = RequestGen::new(ModelKind::Lenet, 1);
    let (img, _) = gen.next();
    let reply = c.infer(42, img.data()).unwrap();
    assert_eq!(reply.get("id").as_f64(), Some(42.0));
    let pred = reply.get("pred").as_f64().unwrap();
    assert!((0.0..10.0).contains(&pred));
    assert!(reply.get("latency_us").as_f64().unwrap() > 0.0);
    srv.stop();
}

#[test]
fn batches_concurrent_clients() {
    let dir = need_artifacts!();
    let cfg = ServerConfig {
        max_delay: Duration::from_millis(20),
        ..Default::default()
    };
    let srv = Server::start(dir, cfg).unwrap();
    let port = srv.port;

    let threads: Vec<_> = (0..8)
        .map(|t| {
            std::thread::spawn(move || {
                let mut c = Client::connect(&format!("127.0.0.1:{port}")).unwrap();
                let mut gen = RequestGen::new(ModelKind::Lenet, t);
                let mut batched = 0u64;
                for i in 0..10 {
                    let (img, _) = gen.next();
                    let reply = c.infer(t * 100 + i, img.data()).unwrap();
                    assert!(reply.get("error").is_null(), "{}", reply.to_json());
                    if reply.get("batch").as_f64().unwrap_or(1.0) > 1.0 {
                        batched += 1;
                    }
                }
                batched
            })
        })
        .collect();
    let batched: u64 = threads.into_iter().map(|t| t.join().unwrap()).sum();
    assert!(
        batched > 0,
        "dynamic batching never formed a multi-request batch across 8 clients"
    );
    assert_eq!(srv.metrics.counter("requests"), 80);
    assert!(srv.metrics.counter("batches") < 80, "no batching happened at all");
    srv.stop();
}

#[test]
fn rejects_malformed_requests_without_dying() {
    let dir = need_artifacts!();
    let srv = Server::start(dir, ServerConfig::default()).unwrap();
    let port = srv.port;

    use std::io::{BufRead, BufReader, Write};
    let stream = std::net::TcpStream::connect(format!("127.0.0.1:{port}")).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut w = stream;

    // garbage json
    w.write_all(b"this is not json\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("error"), "{line}");

    // wrong pixel count
    line.clear();
    w.write_all(b"{\"id\":1,\"pixels\":[1.0,2.0]}\n").unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("error"), "{line}");

    // server still healthy for a valid request
    let mut c = Client::connect(&format!("127.0.0.1:{port}")).unwrap();
    let mut gen = RequestGen::new(ModelKind::Lenet, 3);
    let (img, _) = gen.next();
    let reply = c.infer(5, img.data()).unwrap();
    assert!(reply.get("error").is_null());
    assert_eq!(srv.metrics.counter("bad_requests"), 2);
    srv.stop();
}

#[test]
fn predictions_match_offline_eval() {
    // the served prediction for a test image equals the offline artifact run
    // (engine pinned to PJRT: the batch-aware Auto mode intentionally routes
    // singleton batches to the quantized engine, which this parity check is
    // not about)
    let dir = need_artifacts!();
    use qsq_edge::model::store::Dataset;
    use qsq_edge::repro;
    use qsq_edge::runtime::client::Runtime;
    let test = Dataset::load(&dir, "mnist", "test").unwrap();

    let cfg = ServerConfig {
        engine: qsq_edge::coordinator::server::EngineSelect::Pjrt,
        ..Default::default()
    };
    let srv = Server::start(dir.clone(), cfg).unwrap();
    let mut c = Client::connect(&format!("127.0.0.1:{}", srv.port)).unwrap();
    let mut served = Vec::new();
    for i in 0..16 {
        let img = test.image(i);
        let reply = c.infer(i as u64, img.data()).unwrap();
        served.push(reply.get("pred").as_f64().unwrap() as usize);
    }
    srv.stop();

    // offline: same images through eval path
    let mut rt = Runtime::new(&dir).unwrap();
    let store = qsq_edge::model::store::WeightStore::load(&dir, ModelKind::Lenet).unwrap();
    let exe = rt.load("lenet_fwd_b128").unwrap();
    let mut args = vec![qsq_edge::runtime::client::ArgValue::F32(test.batch(0, 128))];
    args.extend(
        store
            .ordered()
            .into_iter()
            .map(|t| qsq_edge::runtime::client::ArgValue::F32(t.clone())),
    );
    let logits = &exe.run(&args).unwrap()[0];
    let offline = qsq_edge::tensor::ops::argmax_rows(logits);
    assert_eq!(&served[..], &offline[..16]);
    let _ = repro::quantized_names(ModelKind::Lenet); // keep import used
}
