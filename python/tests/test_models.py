"""L2 model graphs: shapes, pallas/ref backend equivalence, QSQ-fused path."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model, qsq_lib
from compile.aot import LENET_QSQ_GROUPS


def _lenet_params(seed=0):
    return model.init_params(model.LENET_SHAPES, model.LENET_PARAM_NAMES, seed)


def _convnet_params(seed=0):
    return model.init_params(model.CONVNET_SHAPES, model.CONVNET_PARAM_NAMES, seed)


def test_lenet_shapes():
    x = jnp.zeros((4, 28, 28, 1), jnp.float32)
    p = _lenet_params()
    assert model.lenet_fwd(x, p).shape == (4, 10)
    assert model.lenet_features(x, p).shape == (4, 84)


def test_convnet_shapes():
    x = jnp.zeros((4, 32, 32, 3), jnp.float32)
    assert model.convnet_fwd(x, _convnet_params()).shape == (4, 10)


def test_lenet_pallas_matches_ref():
    r = np.random.default_rng(0)
    x = jnp.asarray(r.standard_normal((2, 28, 28, 1)), jnp.float32)
    p = _lenet_params()
    a = model.lenet_fwd(x, p, backend="ref")
    b = model.lenet_fwd(x, p, backend="pallas")
    np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3)


def test_convnet_pallas_matches_ref():
    r = np.random.default_rng(0)
    x = jnp.asarray(r.standard_normal((1, 32, 32, 3)), jnp.float32)
    p = _convnet_params()
    a = model.convnet_fwd(x, p, backend="ref")
    b = model.convnet_fwd(x, p, backend="pallas")
    np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3)


def _qsq_args(params_dict, groups):
    qargs, decoded = [], dict(params_dict)
    for n in model.LENET_QUANTIZED:
        qt = qsq_lib.quantize_matrix(params_dict[n], group=groups[n], phi=4, mode="nearest")
        qargs += [jnp.asarray(qt.codes), jnp.asarray(qt.scalars)]
        decoded[n] = jnp.asarray(qt.decode())
    return qargs, decoded


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_lenet_qsq_fused_equals_decode_then_fwd(backend):
    """fwd_qsq(codes) == fwd(decode(codes)) — the fused-kernel contract."""
    r = np.random.default_rng(1)
    x = jnp.asarray(r.standard_normal((2, 28, 28, 1)), jnp.float32)
    p = _lenet_params(1)
    pd = dict(zip(model.LENET_PARAM_NAMES, p))
    qargs, decoded = _qsq_args(pd, LENET_QSQ_GROUPS)
    fp = [pd[n] for n in ("c1b", "c2b", "f1b", "f2b", "f3w", "f3b")]
    got = model.lenet_fwd_qsq(x, qargs, fp, LENET_QSQ_GROUPS, backend=backend)
    want = model.lenet_fwd(x, [decoded[n] for n in model.LENET_PARAM_NAMES], backend="ref")
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_fc_step_decreases_loss():
    r = np.random.default_rng(0)
    feat = jnp.asarray(r.standard_normal((128, 84)), jnp.float32)
    y = r.integers(0, 10, 128)
    y1h = jnp.asarray(np.eye(10, dtype=np.float32)[y])
    w = jnp.asarray(r.standard_normal((84, 10)) * 0.1, jnp.float32)
    b = jnp.zeros((10,), jnp.float32)
    l0, w, b = model.fc_step(feat, y1h, w, b, jnp.float32(0.1))
    l_prev = float(l0)
    for _ in range(5):
        l, w, b = model.fc_step(feat, y1h, w, b, jnp.float32(0.1))
        assert float(l) <= l_prev + 1e-4
        l_prev = float(l)


def test_fc_step_gradient_matches_analytic():
    """d/dW of softmax-CE == feat^T (p - y)/B — pins the AOT'd step."""
    r = np.random.default_rng(3)
    feat = jnp.asarray(r.standard_normal((16, 84)), jnp.float32)
    y = r.integers(0, 10, 16)
    y1h = jnp.asarray(np.eye(10, dtype=np.float32)[y])
    w = jnp.asarray(r.standard_normal((84, 10)) * 0.1, jnp.float32)
    b = jnp.zeros((10,), jnp.float32)
    lr = 0.5
    _, w2, b2 = model.fc_step(feat, y1h, w, b, jnp.float32(lr))
    logits = feat @ w + b
    p = jax.nn.softmax(logits)
    gw = feat.T @ (p - y1h) / 16.0
    gb = jnp.mean(p - y1h, axis=0)
    np.testing.assert_allclose(w2, w - lr * gw, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(b2, b - lr * gb, rtol=1e-4, atol=1e-5)


def test_init_params_shapes():
    p = _lenet_params()
    for arr, name in zip(p, model.LENET_PARAM_NAMES):
        assert arr.shape == model.LENET_SHAPES[name]
    # biases start at zero
    assert float(jnp.abs(p[1]).max()) == 0.0


def test_qsq_groups_divide_k():
    for n, g in LENET_QSQ_GROUPS.items():
        shp = model.LENET_SHAPES[n]
        k = int(np.prod(shp[:-1]))
        assert k % g == 0, (n, k, g)
