"""QSQ quantizer (qsq_lib) properties — mirror of rust quant::qsq."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import qsq_lib

_SET = dict(deadline=None, max_examples=30)


def _w(seed, k=24, oc=8, scale=0.1):
    return (np.random.default_rng(seed).standard_normal((k, oc)) * scale).astype(np.float32)


@settings(**_SET)
@given(
    seed=st.integers(0, 2**31 - 1),
    phi=st.sampled_from([1, 2, 4]),
    group=st.sampled_from([2, 3, 4, 6, 8, 12, 24]),
    mode=st.sampled_from(["sigma-search", "sigma", "nearest", "nearest-opt"]),
)
def test_decode_values_are_shiftable(seed, phi, group, mode):
    """Every decoded value is level*alpha with level in the phi level set."""
    w = _w(seed)
    qt = qsq_lib.quantize_matrix(w, group=group, phi=phi, mode=mode)
    levels = set(float(v) for v in qsq_lib.levels_for_phi(phi))
    dec = qt.decode()
    alpha = np.repeat(qt.scalars, group, axis=0)
    with np.errstate(divide="ignore", invalid="ignore"):
        ratio = np.where(alpha != 0, dec / np.where(alpha == 0, 1, alpha), 0.0)
    for v in np.unique(np.abs(np.round(ratio, 5))):
        assert float(v) in levels, f"decoded ratio {v} outside levels {levels}"


@settings(**_SET)
@given(seed=st.integers(0, 2**31 - 1), phi=st.sampled_from([1, 2, 4]))
def test_codes_within_phi_range(seed, phi):
    w = _w(seed)
    qt = qsq_lib.quantize_matrix(w, group=4, phi=phi, mode="nearest")
    mags = np.abs(qsq_lib.LUT[qt.codes.astype(np.int32)])
    assert mags.max() <= max(qsq_lib.levels_for_phi(phi))
    assert set(np.unique(qt.codes)) <= set(range(7))  # code 7 never emitted


@settings(**_SET)
@given(seed=st.integers(0, 2**31 - 1), group=st.sampled_from([2, 4, 8]))
def test_nearest_error_monotone_in_phi(seed, group):
    """More quantization levels never hurt the eq.-5 objective (nearest mode)."""
    w = _w(seed)
    errs = [
        qsq_lib.quantization_error(w, qsq_lib.quantize_matrix(w, group=group, phi=phi, mode="nearest"))
        for phi in (1, 2, 4)
    ]
    assert errs[0] >= errs[1] - 1e-5 and errs[1] >= errs[2] - 1e-5


@settings(**_SET)
@given(seed=st.integers(0, 2**31 - 1), phi=st.sampled_from([1, 2, 4]))
def test_nearest_beats_sigma_rule(seed, phi):
    """Nearest-level assignment is optimal for eq. 5 given eq.-9 alpha."""
    w = _w(seed)
    e_near = qsq_lib.quantization_error(w, qsq_lib.quantize_matrix(w, group=4, phi=phi, mode="nearest"))
    e_sig = qsq_lib.quantization_error(w, qsq_lib.quantize_matrix(w, group=4, phi=phi, mode="sigma-search"))
    assert e_near <= e_sig + 1e-5


@settings(**_SET)
@given(seed=st.integers(0, 2**31 - 1), phi=st.sampled_from([1, 2, 4]))
def test_alpha_search_beats_eq9(seed, phi):
    w = _w(seed)
    e_opt = qsq_lib.quantization_error(w, qsq_lib.quantize_matrix(w, group=4, phi=phi, mode="nearest-opt"))
    e_eq9 = qsq_lib.quantization_error(w, qsq_lib.quantize_matrix(w, group=4, phi=phi, mode="nearest"))
    assert e_opt <= e_eq9 + 1e-5


def test_alpha_eq9():
    """alpha = mean(|v|)/phi exactly (eq. 9)."""
    w = np.array([[1.0], [2.0], [3.0], [-2.0]], dtype=np.float32)
    qt = qsq_lib.quantize_matrix(w, group=4, phi=4, mode="nearest")
    assert qt.scalars.shape == (1, 1)
    np.testing.assert_allclose(qt.scalars[0, 0], 2.0 / 4.0, rtol=1e-6)


def test_code_bits_eq8():
    # phi=1 -> ternary-ish 2 bits; phi=2,4 -> 3 bits (eq. 8)
    assert qsq_lib.code_bits(1) == 2
    assert qsq_lib.code_bits(2) == 3
    assert qsq_lib.code_bits(4) == 3


def test_bit_accounting_eq11_eq12():
    shape = (5, 5, 6, 16)  # LeNet c2w
    full = qsq_lib.full_precision_bits(shape)
    assert full == 2400 * 32
    qt = qsq_lib.quantize_matrix(np.random.default_rng(0).standard_normal(shape).astype(np.float32), group=6, phi=4)
    enc = qsq_lib.encoded_bits(qt)
    assert enc == 2400 * 3 + (2400 // 6) * 32
    assert enc < full


def test_zero_weights_all_zero_codes():
    w = np.zeros((8, 2), dtype=np.float32)
    qt = qsq_lib.quantize_matrix(w, group=4, phi=4, mode="nearest")
    assert (qt.codes == 0).all()
    assert (qt.decode() == 0).all()


def test_group_must_divide():
    with pytest.raises(AssertionError):
        qsq_lib.quantize_matrix(_w(0, k=10), group=3, phi=4)


@settings(**_SET)
@given(seed=st.integers(0, 2**31 - 1))
def test_decode_shape_roundtrip_conv(seed):
    w = (np.random.default_rng(seed).standard_normal((5, 5, 6, 16)) * 0.1).astype(np.float32)
    qt = qsq_lib.quantize_matrix(w, group=6, phi=4, mode="nearest")
    assert qt.decode().shape == w.shape
    assert qt.codes.shape == (150, 16)
    assert qt.scalars.shape == (25, 16)
