"""Synthetic dataset generators: determinism, shapes, value ranges, balance."""

import numpy as np

from compile import data as dg


def test_mnist_shapes_and_range():
    x, y = dg.synth_mnist(64, seed=7)
    assert x.shape == (64, 28, 28, 1) and x.dtype == np.float32
    assert y.shape == (64,) and y.dtype == np.int32
    assert 0.0 <= x.min() and x.max() <= 1.0
    assert set(np.unique(y)) <= set(range(10))


def test_cifar_shapes_and_range():
    x, y = dg.synth_cifar(64, seed=7)
    assert x.shape == (64, 32, 32, 3) and x.dtype == np.float32
    assert 0.0 <= x.min() and x.max() <= 1.0


def test_determinism():
    a = dg.synth_mnist(16, seed=5)
    b = dg.synth_mnist(16, seed=5)
    np.testing.assert_array_equal(a[0], b[0])
    np.testing.assert_array_equal(a[1], b[1])
    c = dg.synth_cifar(16, seed=5)
    d = dg.synth_cifar(16, seed=5)
    np.testing.assert_array_equal(c[0], d[0])


def test_seeds_differ():
    a, _ = dg.synth_mnist(16, seed=1)
    b, _ = dg.synth_mnist(16, seed=2)
    assert np.abs(a - b).max() > 0.1


def test_class_balance():
    _, y = dg.synth_mnist(2000, seed=0)
    counts = np.bincount(y, minlength=10)
    assert counts.min() > 120  # roughly uniform


def test_classes_distinguishable():
    """Mean image of each digit class differs from every other class."""
    x, y = dg.synth_mnist(1500, seed=3)
    means = np.stack([x[y == d].mean(axis=0) for d in range(10)])
    for i in range(10):
        for j in range(i + 1, 10):
            assert np.abs(means[i] - means[j]).mean() > 0.01, (i, j)


def test_cifar_colour_separation():
    x, y = dg.synth_cifar(1500, seed=3)
    mean_rgb = np.stack([x[y == c].mean(axis=(0, 1, 2)) for c in range(10)])
    # red-circle class 0 must be redder than green-square class 2
    assert mean_rgb[0, 0] > mean_rgb[2, 0]
    assert mean_rgb[2, 1] > mean_rgb[0, 1]
