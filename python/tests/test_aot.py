"""AOT layer: artifact definitions are self-consistent and lower to HLO text."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model


def test_artifact_defs_consistent():
    defs = aot.artifact_defs()
    names = [d["name"] for d in defs]
    assert len(names) == len(set(names)), "duplicate artifact names"
    expected = {
        "lenet_fwd_b1", "lenet_fwd_b32", "lenet_fwd_b128",
        "convnet_fwd_b1", "convnet_fwd_b32", "convnet_fwd_b128",
        "lenet_features_b128", "fc_step_b128",
        "lenet_fwd_qsq_b32", "lenet_fwd_qsq_ref_b32", "csd_matmul_demo",
    }
    assert expected <= set(names)
    for d in defs:
        for (argname, shape, dt) in d["args"]:
            assert dt in ("f32", "i8", "i32"), (d["name"], argname)


def test_artifact_fns_trace():
    """Every artifact function traces (eval_shape) with its declared specs."""
    for d in aot.artifact_defs():
        specs = [jax.ShapeDtypeStruct(s, aot._DT[t]) for (_, s, t) in d["args"]]
        out = jax.eval_shape(d["fn"], *specs)
        if not isinstance(out, (tuple, list)):
            out = (out,)
        assert all(o.dtype == jnp.float32 for o in out), d["name"]


def test_hlo_text_lowering_smoke():
    """to_hlo_text produces parseable HLO for a small jitted function."""

    def f(x, y):
        return (jnp.dot(x, y) + 1.0,)

    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    text = aot.to_hlo_text(jax.jit(f).lower(spec, spec))
    assert "ENTRY" in text and "f32[4,4]" in text


def test_hlo_text_lowering_pallas_qsq():
    """The fused Pallas QSQ kernel lowers to plain HLO (no custom-calls that
    the CPU PJRT client can't run)."""
    from compile.kernels import qsq as kqsq

    def f(x, c, s):
        return (kqsq.qsq_dense(x, c, s, 4),)

    text = aot.to_hlo_text(
        jax.jit(f).lower(
            jax.ShapeDtypeStruct((8, 8), jnp.float32),
            jax.ShapeDtypeStruct((8, 16), jnp.int8),
            jax.ShapeDtypeStruct((2, 16), jnp.float32),
        )
    )
    assert "ENTRY" in text
    assert "custom-call" not in text.lower(), "Mosaic custom-call leaked into CPU artifact"


def test_qsq_arg_shapes_match_manifest_groups():
    qargs = aot._qsq_arg_shapes(aot.LENET_QSQ_GROUPS)
    by_name = {n: s for (n, s, _) in qargs}
    assert by_name["c1w_codes"] == (25, 6)
    assert by_name["c1w_scalars"] == (5, 6)
    assert by_name["f1w_codes"] == (256, 120)
    assert by_name["f1w_scalars"] == (16, 120)
