"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes (and code/group configurations); allclose against
ref.py is THE signal that lets models train on the ref path and serve on the
Pallas path (see kernels/ref.py docstring).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv as kconv
from compile.kernels import csd as kcsd
from compile.kernels import qsq as kqsq
from compile.kernels import ref

_SET = dict(deadline=None, max_examples=20)


def _rng(seed):
    return np.random.default_rng(seed)


@settings(**_SET)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 70),
    n=st.integers(1, 70),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    r = _rng(seed)
    x = jnp.asarray(r.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(r.standard_normal((k, n)), jnp.float32)
    got = kconv.matmul(x, w)
    want = ref.matmul(x, w)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_matmul_tiled_multiblock():
    # force several grid steps in every dimension
    r = _rng(0)
    x = jnp.asarray(r.standard_normal((130, 300)), jnp.float32)
    w = jnp.asarray(r.standard_normal((300, 140)), jnp.float32)
    got = kconv.matmul(x, w, bm=64, bk=128, bn=64)
    np.testing.assert_allclose(got, ref.matmul(x, w), rtol=1e-3, atol=1e-3)


@settings(**_SET)
@given(
    groups=st.integers(1, 6),
    group=st.sampled_from([1, 2, 4, 8]),
    n=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_qsq_decode_matches_ref(groups, group, n, seed):
    r = _rng(seed)
    k = groups * group
    codes = jnp.asarray(r.integers(0, 7, (k, n)), jnp.int8)
    scal = jnp.asarray(r.standard_normal((groups, n)).astype(np.float32))
    got = kqsq.qsq_decode(codes, scal, group)
    want = ref.qsq_decode(codes, scal, group)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_qsq_decode_table2_semantics():
    # code -> multiplier exactly per Table II, incl. the unused 111 pattern
    scal = jnp.ones((1, 8), jnp.float32) * 0.5
    codes = jnp.asarray(np.arange(8).reshape(1, 8), jnp.int8)
    got = np.asarray(kqsq.qsq_decode(codes, scal, 1))[0]
    np.testing.assert_allclose(got, [0.0, 0.5, 1.0, 2.0, -0.5, -1.0, -2.0, 0.0])


@settings(**_SET)
@given(
    m=st.integers(1, 60),
    groups=st.integers(1, 6),
    group=st.sampled_from([1, 2, 4, 8]),
    n=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_qsq_dense_matches_ref(m, groups, group, n, seed):
    r = _rng(seed)
    k = groups * group
    x = jnp.asarray(r.standard_normal((m, k)), jnp.float32)
    codes = jnp.asarray(r.integers(0, 7, (k, n)), jnp.int8)
    scal = jnp.asarray(r.standard_normal((groups, n)).astype(np.float32))
    got = kqsq.qsq_dense(x, codes, scal, group)
    want = ref.qsq_dense(x, codes, scal, group)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_qsq_dense_multiblock_padding():
    # padded codes decode to exactly zero — multi-tile result must equal ref
    r = _rng(1)
    m, k, n, group = 150, 24, 135, 6
    x = jnp.asarray(r.standard_normal((m, k)), jnp.float32)
    codes = jnp.asarray(r.integers(0, 7, (k, n)), jnp.int8)
    scal = jnp.asarray(r.standard_normal((k // group, n)).astype(np.float32))
    got = kqsq.qsq_dense(x, codes, scal, group, bm=64, bn=64)
    np.testing.assert_allclose(got, ref.qsq_dense(x, codes, scal, group), rtol=1e-4, atol=1e-4)


@settings(**_SET)
@given(
    m=st.integers(1, 50),
    k=st.integers(1, 50),
    n=st.integers(1, 50),
    digits=st.integers(1, 5),
    seed=st.integers(0, 2**31 - 1),
)
def test_csd_matmul_matches_ref(m, k, n, digits, seed):
    r = _rng(seed)
    x = jnp.asarray(r.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(r.standard_normal((k, n)), jnp.float32)
    got = kcsd.csd_matmul(x, w, digits)
    want = ref.csd_matmul(x, w, digits)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(**_SET)
@given(digits=st.integers(1, 8), seed=st.integers(0, 2**31 - 1))
def test_csd_approx_error_shrinks(digits, seed):
    """Each extra CSD digit reduces (or keeps) the worst-case relative error."""
    r = _rng(seed)
    w = jnp.asarray(r.standard_normal(256) * 3.0, jnp.float32)
    e1 = float(jnp.max(jnp.abs(ref.csd_approx(w, digits) - w)))
    e2 = float(jnp.max(jnp.abs(ref.csd_approx(w, digits + 1) - w)))
    assert e2 <= e1 + 1e-6


def test_csd_approx_exact_for_powers_of_two():
    w = jnp.asarray([1.0, -2.0, 0.5, 4.0, -0.25, 0.0], jnp.float32)
    np.testing.assert_allclose(ref.csd_approx(w, 1), w, rtol=1e-6)


@settings(**_SET)
@given(
    b=st.integers(1, 4),
    hw=st.sampled_from([6, 9, 12]),
    c=st.integers(1, 4),
    oc=st.integers(1, 6),
    kk=st.sampled_from([3, 5]),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv_oracle_matches_lax(b, hw, c, oc, kk, seed):
    """The im2col conv oracle == XLA's native convolution."""
    r = _rng(seed)
    x = jnp.asarray(r.standard_normal((b, hw, hw, c)), jnp.float32)
    w = jnp.asarray(r.standard_normal((kk, kk, c, oc)), jnp.float32)
    want = jax.lax.conv_general_dilated(
        x, w, (1, 1), "VALID", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )
    np.testing.assert_allclose(ref.conv2d_nhwc(x, w), want, rtol=1e-3, atol=1e-3)


def test_maxpool2():
    x = jnp.arange(16, dtype=jnp.float32).reshape(1, 4, 4, 1)
    got = np.asarray(ref.maxpool2(x))[0, :, :, 0]
    np.testing.assert_allclose(got, [[5, 7], [13, 15]])


def test_qsq_dense_rejects_bad_group():
    x = jnp.zeros((2, 10), jnp.float32)
    codes = jnp.zeros((10, 3), jnp.int8)
    scal = jnp.zeros((3, 3), jnp.float32)
    with pytest.raises(AssertionError):
        kqsq.qsq_dense(x, codes, scal, 3)  # 3 does not divide 10
