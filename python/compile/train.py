"""Build-time training (pure JAX; the paper used Keras).

Trains LeNet-5 on synth-mnist and ConvNet-4 on synth-cifar with SGD+momentum
on the "ref" compute path (XLA-native; pinned equal to the Pallas path by
pytest), then writes:

  artifacts/weights/{lenet,convnet}/<tensor>.npy
  artifacts/data/{mnist,cifar}_{train,test}_{x,y}.npy
  (metrics returned to aot.py for the manifest)

Run via ``make artifacts`` (aot.py imports and drives this); never at request
time.
"""

from __future__ import annotations

import functools
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from compile import data as datagen
from compile import model

# Sizes chosen so every split is a multiple of the largest artifact batch
# (128): train 7936 = 62*128, test 2048 = 16*128.
TRAIN_N, TEST_N = 7936, 2048
BATCH = 128


def _one_hot(y, n=10):
    return jnp.eye(n, dtype=jnp.float32)[y]


def _loss(params, x, y1h, fwd):
    return model.softmax_xent(fwd(x, params), y1h)


@functools.partial(jax.jit, static_argnames=("fwd", "lr", "mom"))
def _step(params, vel, x, y1h, fwd, lr=0.05, mom=0.9):
    loss, grads = jax.value_and_grad(_loss)(params, x, y1h, fwd)
    vel = [mom * v - lr * g for v, g in zip(vel, grads)]
    params = [p + v for p, v in zip(params, vel)]
    return params, vel, loss


def accuracy(fwd, params, x, y, batch=BATCH):
    hits = 0
    for i in range(0, x.shape[0], batch):
        logits = fwd(jnp.asarray(x[i : i + batch]), params)
        hits += int((jnp.argmax(logits, axis=1) == jnp.asarray(y[i : i + batch])).sum())
    return hits / x.shape[0]


def train_model(name: str, epochs: int, lr: float, seed: int = 0, log=print):
    if name == "lenet":
        xtr, ytr = datagen.synth_mnist(TRAIN_N, seed=1)
        xte, yte = datagen.synth_mnist(TEST_N, seed=2)
        fwd = functools.partial(model.lenet_fwd, backend="ref")
        params = model.init_params(model.LENET_SHAPES, model.LENET_PARAM_NAMES, seed)
        pnames = model.LENET_PARAM_NAMES
    elif name == "convnet":
        xtr, ytr = datagen.synth_cifar(TRAIN_N, seed=3)
        xte, yte = datagen.synth_cifar(TEST_N, seed=4)
        fwd = functools.partial(model.convnet_fwd, backend="ref")
        params = model.init_params(model.CONVNET_SHAPES, model.CONVNET_PARAM_NAMES, seed)
        pnames = model.CONVNET_PARAM_NAMES
    else:
        raise ValueError(name)

    fwd_jit = jax.jit(lambda x, p: fwd(x, p))
    vel = [jnp.zeros_like(p) for p in params]
    rng = np.random.default_rng(seed)
    t0 = time.time()
    for ep in range(epochs):
        order = rng.permutation(TRAIN_N)
        tot = 0.0
        lr_ep = lr * (0.5 ** (ep // 3))  # step decay: halve every 3 epochs
        for i in range(0, TRAIN_N, BATCH):
            idx = order[i : i + BATCH]
            params, vel, loss = _step(
                params, vel, jnp.asarray(xtr[idx]), _one_hot(jnp.asarray(ytr[idx])), fwd, lr=lr_ep
            )
            tot += float(loss)
        acc = accuracy(fwd_jit, params, xte, yte)
        log(f"[train:{name}] epoch {ep+1}/{epochs} loss={tot/(TRAIN_N//BATCH):.4f} test_acc={acc:.4f} ({time.time()-t0:.0f}s)")
    final = accuracy(fwd_jit, params, xte, yte)
    return {
        "params": {n: np.asarray(p) for n, p in zip(pnames, params)},
        "test_acc": final,
        "data": {"train_x": xtr, "train_y": ytr, "test_x": xte, "test_y": yte},
    }


def save_all(out_dir: str, log=print):
    """Train both models, write weights + datasets, return metrics dict."""
    metrics = {}
    datasets = {"lenet": "mnist", "convnet": "cifar"}
    epochs = {"lenet": 8, "convnet": 12}
    lrs = {"lenet": 0.05, "convnet": 0.05}
    for name in ("lenet", "convnet"):
        res = train_model(name, epochs[name], lrs[name], log=log)
        wdir = os.path.join(out_dir, "weights", name)
        os.makedirs(wdir, exist_ok=True)
        for pname, arr in res["params"].items():
            np.save(os.path.join(wdir, f"{pname}.npy"), arr)
        ddir = os.path.join(out_dir, "data")
        os.makedirs(ddir, exist_ok=True)
        ds = datasets[name]
        for split in ("train", "test"):
            np.save(os.path.join(ddir, f"{ds}_{split}_x.npy"), res["data"][f"{split}_x"])
            np.save(os.path.join(ddir, f"{ds}_{split}_y.npy"), res["data"][f"{split}_y"].astype(np.int32))
        metrics[f"{name}_test_acc"] = res["test_acc"]
        log(f"[train:{name}] final test_acc={res['test_acc']:.4f}")
    return metrics
