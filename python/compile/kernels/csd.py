"""L1 Pallas kernel for the Quality Scalable Multiplier (value model).

The paper's QSM converts the multiplicand to Canonic Signed Digit form and
truncates least-significant non-zero digits, trading partial products (energy)
for accuracy.  A TPU MXU exposes no bit-level multiplier, so the kernel
models the *value* effect: project each weight onto its k-term signed-power-
of-two expansion (greedy, most significant digit first) before the matmul.
The bit-accurate partial-product/energy accounting is the rust ``hw::csd`` /
``hw::multiplier`` simulator; rust tests pin its value semantics to this
kernel's.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _csd_approx_block(w: jax.Array, digits: int) -> jax.Array:
    out = jnp.zeros_like(w)
    r = w
    for _ in range(digits):
        mag = jnp.abs(r)
        nz = mag > 1e-30
        e = jnp.floor(jnp.log2(jnp.maximum(mag, 1e-30) * (4.0 / 3.0)))
        term = jnp.where(nz, jnp.sign(r) * jnp.exp2(e), 0.0)
        out = out + term
        r = r - term
    return out


def _csd_mm_kernel(x_ref, w_ref, o_ref, *, digits: int):
    w = _csd_approx_block(w_ref[...], digits)
    o_ref[...] = jnp.dot(x_ref[...], w, preferred_element_type=jnp.float32)


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def csd_matmul(
    x: jax.Array,
    w: jax.Array,
    digits: int,
    *,
    bm: int = 128,
    bn: int = 128,
) -> jax.Array:
    """x [M,K] @ csd_approx(w [K,N], digits) -> [M,N].

    K stays whole per grid step (weights decoded once per tile), grid walks
    (M/bm, N/bn) — same schedule as the fused QSQ kernel.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2

    bm_ = min(bm, _round_up(m, 8))
    bn_ = min(bn, _round_up(n, 8))
    mp, np_, kp = _round_up(m, bm_), _round_up(n, bn_), _round_up(k, 8)

    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n)))

    grid = (mp // bm_, np_ // bn_)
    out = pl.pallas_call(
        functools.partial(_csd_mm_kernel, digits=digits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, kp), lambda i, j: (i, 0)),
            pl.BlockSpec((kp, bn_), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp)
    return out[:m, :n]
