"""Pure-jnp reference oracles for every Pallas kernel (L1 correctness signal).

Every kernel in this package has a mathematically identical implementation
here, written with plain `jax.numpy` / `lax` ops.  pytest (with hypothesis
shape sweeps) asserts `assert_allclose(kernel(...), ref(...))`.

The same functions double as the *training-time* compute path: interpret-mode
Pallas is orders of magnitude slower than XLA-native ops on CPU, so
`model.py` uses these refs during training and the Pallas kernels in the AOT
artifacts — the tests here are what make that swap sound.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Decode LUT, Table II of the paper.  Code 0..7 -> level multiplier.
# 0:0  1:+1  2:+2  3:+4  4:-1  5:-2  6:-4  7:unused (decodes to 0)
DECODE_LUT = jnp.array([0.0, 1.0, 2.0, 4.0, -1.0, -2.0, -4.0, 0.0], dtype=jnp.float32)

# Level multipliers available at each quality setting phi.
PHI_LEVELS = {1: (0.0, 1.0), 2: (0.0, 1.0, 2.0), 4: (0.0, 1.0, 2.0, 4.0)}


def qsq_decode(codes: jax.Array, scalars: jax.Array, group: int) -> jax.Array:
    """Decode 3-bit QSQ codes to approximate weights.

    codes   int8/int32 [K, ...]: Table-II codes, grouped along axis 0 in
            contiguous runs of `group` rows sharing one scalar.
    scalars f32 [K/group, ...]: per-group full-precision scalar (alpha).
    Returns f32 array shaped like `codes`.
    """
    k = codes.shape[0]
    assert k % group == 0, f"leading dim {k} not divisible by group {group}"
    lvl = DECODE_LUT[codes.astype(jnp.int32)]
    alpha = jnp.repeat(scalars, group, axis=0)
    return lvl * alpha.astype(jnp.float32)


def matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """Plain f32 matmul oracle for the tiled Pallas matmul."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def qsq_dense(x: jax.Array, codes: jax.Array, scalars: jax.Array, group: int) -> jax.Array:
    """Fused decode + matmul oracle: x [B,IN] @ decode(codes [IN,OUT])."""
    return matmul(x, qsq_decode(codes, scalars, group))


def csd_approx(w: jax.Array, digits: int) -> jax.Array:
    """Project each value onto its `digits`-term signed-power-of-two expansion.

    Greedy most-significant-first expansion: at each step subtract the nearest
    signed power of two of the residual.  This is the value-level model of the
    paper's quality-scalable CSD multiplier (truncate least-significant
    non-zero digits -> fewer partial products).  The bit-accurate integer CSD
    (with the non-adjacency property and partial-product counting) lives in
    the rust `hw::csd` module; tests there check agreement with this value
    model.
    """
    out = jnp.zeros_like(w)
    r = w
    for _ in range(digits):
        mag = jnp.abs(r)
        nz = mag > 1e-30
        # nearest power of two: 2^floor(log2(4/3 * |r|))
        e = jnp.floor(jnp.log2(jnp.maximum(mag, 1e-30) * (4.0 / 3.0)))
        term = jnp.where(nz, jnp.sign(r) * jnp.exp2(e), 0.0)
        out = out + term
        r = r - term
    return out


def csd_matmul(x: jax.Array, w: jax.Array, digits: int) -> jax.Array:
    """Approximate matmul with the multiplicand (weights) CSD-truncated."""
    return matmul(x, csd_approx(w, digits))


def im2col(x: jax.Array, kh: int, kw: int, stride: int = 1):
    """Extract VALID conv patches -> ([B*H'*W', kh*kw*C], H', W').

    Patch element ordering is (di, dj, c) — row-major over the kernel window,
    channel fastest — matching `w.reshape(kh*kw*C, OC)` for w [kh,kw,C,OC].
    """
    b, h, w_, c = x.shape
    oh = (h - kh) // stride + 1
    ow = (w_ - kw) // stride + 1
    cols = []
    for di in range(kh):
        for dj in range(kw):
            sl = x[:, di : di + oh * stride : stride, dj : dj + ow * stride : stride, :]
            cols.append(sl)
    # [B, H', W', kh*kw, C] -> [B*H'*W', kh*kw*C]
    patches = jnp.stack(cols, axis=3)
    return patches.reshape(b * oh * ow, kh * kw * c), oh, ow


def conv2d_nhwc(x: jax.Array, w: jax.Array, stride: int = 1) -> jax.Array:
    """VALID conv oracle, NHWC x [B,H,W,C] * w [kh,kw,C,OC] -> [B,H',W',OC].

    Implemented as im2col + matmul so the patch ordering is *identical* to the
    Pallas path; cross-checked against lax.conv_general_dilated in tests.
    """
    patches, oh, ow = im2col(x, w.shape[0], w.shape[1], stride)
    b = x.shape[0]
    wf = w.reshape(-1, w.shape[3])
    out = matmul(patches, wf)
    return out.reshape(b, oh, ow, w.shape[3])


def maxpool2(x: jax.Array) -> jax.Array:
    """2x2 max-pool, stride 2, NHWC. H and W must be even."""
    b, h, w, c = x.shape
    return x.reshape(b, h // 2, 2, w // 2, 2, c).max(axis=(2, 4))
