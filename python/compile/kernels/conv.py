"""L1 Pallas tiled matmul — the conv/dense compute hot-spot.

Convolutions in this system are im2col + matmul (patch extraction is cheap
data movement and stays in the L2 graph; the FLOPs live here).  The kernel is
a classic MXU-shaped tiled matmul: grid over (M/bm, N/bn, K/bk) with an
accumulating output block, f32 accumulation.

interpret=True is mandatory on this image (CPU PJRT); block shapes are chosen
for the TPU VMEM/MXU discussion in DESIGN.md §8 but the correctness contract
(vs ``ref.matmul``) is backend-independent.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mm_kernel(x_ref, w_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    bm: int = 128,
    bk: int = 256,
    bn: int = 128,
) -> jax.Array:
    """Tiled f32 matmul x [M,K] @ w [K,N] -> [M,N] (zero-padded to tiles)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)

    bm_ = min(bm, _round_up(m, 8))
    bk_ = min(bk, _round_up(k, 8))
    bn_ = min(bn, _round_up(n, 8))
    mp, kp, np_ = _round_up(m, bm_), _round_up(k, bk_), _round_up(n, bn_)

    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n)))

    grid = (mp // bm_, np_ // bn_, kp // bk_)
    out = pl.pallas_call(
        _mm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp)
    return out[:m, :n]
