"""L1 Pallas kernels for Quality Scalable Quantization (QSQ).

Two kernels:

- ``qsq_decode``  — the on-chip shift-and-scale decoder (paper Table II) as an
  elementwise kernel: 3-bit codes (int8 carriers) + one f32 scalar per group
  of N weights -> approximate f32 weights.
- ``qsq_dense``   — the flagship *fused* kernel: decode a weight tile inside
  VMEM and immediately feed the MXU matmul.  This is the TPU analog of the
  paper's decode-on-load ASIC datapath: HBM traffic is codes + scalars, never
  full-precision weights.  BlockSpec expresses the HBM<->VMEM schedule.

Both MUST run with ``interpret=True``: real TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute (see DESIGN.md §3).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

def _decode_block(codes_blk: jax.Array, scalars_blk: jax.Array, group: int) -> jax.Array:
    """Shift-and-scale decode of one VMEM-resident block (Table II).

    codes_blk  int8 [K, N] — Table-II codes.
    scalars_blk f32 [K//group, N] — per-group alpha.

    Computed arithmetically (shift = exp2, invert = sign flip) rather than via
    a LUT gather: pallas kernels may not capture array constants, and this is
    also the faithful model of the shift-and-scale decoder hardware.
    """
    c = codes_blk.astype(jnp.int32)
    neg = c >= 4
    shift = jnp.where(neg, c - 4, c - 1).astype(jnp.float32)
    mag = jnp.exp2(shift)
    zero = (c == 0) | (c == 7)
    lvl = jnp.where(zero, 0.0, jnp.where(neg, -mag, mag))
    alpha = jnp.repeat(scalars_blk, group, axis=0)
    return lvl * alpha


def _qsq_decode_kernel(codes_ref, scalars_ref, o_ref, *, group: int):
    o_ref[...] = _decode_block(codes_ref[...], scalars_ref[...], group)


def qsq_decode(codes: jax.Array, scalars: jax.Array, group: int) -> jax.Array:
    """Decode codes [K, N] + scalars [K//group, N] -> weights f32 [K, N].

    Single-block kernel (weight tensors in this system are far below VMEM
    capacity; the fused qsq_dense kernel is the tiled one).
    """
    k, n = codes.shape
    assert k % group == 0, f"K={k} not divisible by group={group}"
    assert scalars.shape == (k // group, n), (scalars.shape, (k // group, n))
    return pl.pallas_call(
        functools.partial(_qsq_decode_kernel, group=group),
        out_shape=jax.ShapeDtypeStruct((k, n), jnp.float32),
        interpret=True,
    )(codes, scalars)


def _qsq_dense_kernel(x_ref, codes_ref, scalars_ref, o_ref, *, group: int):
    """Fused decode+matmul over one (bm, K)x(K, bn) tile pair.

    The full K (contraction) dimension is resident per grid step, so each
    weight tile is decoded exactly once; the grid walks (M/bm, N/bn).
    """
    w = _decode_block(codes_ref[...], scalars_ref[...], group)
    o_ref[...] = jnp.dot(x_ref[...], w, preferred_element_type=jnp.float32)


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def qsq_dense(
    x: jax.Array,
    codes: jax.Array,
    scalars: jax.Array,
    group: int,
    *,
    bm: int = 128,
    bn: int = 128,
) -> jax.Array:
    """Fused decode + matmul: x [M,K] @ decode(codes [K,N]) -> [M,N].

    Tiles over (M, N); K stays whole per step so scalar groups never straddle
    a block boundary.  Padding uses code 0 (decodes to exactly 0.0), so the
    padded contraction is a no-op — an invariant the pytest suite checks.
    """
    m, k = x.shape
    k2, n = codes.shape
    assert k == k2 and k % group == 0
    assert scalars.shape == (k // group, n)

    mp = _round_up(m, min(bm, _round_up(m, 8)))
    np_ = _round_up(n, min(bn, _round_up(n, 8)))
    bm_ = min(bm, mp)
    bn_ = min(bn, np_)
    mp = _round_up(m, bm_)
    np_ = _round_up(n, bn_)
    kp = _round_up(k, math.lcm(group, 8))

    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    cp = jnp.pad(codes, ((0, kp - k), (0, np_ - n)))  # pad code = 0 -> decodes to 0
    sp = jnp.pad(scalars, ((0, (kp - k) // group), (0, np_ - n)))

    grid = (mp // bm_, np_ // bn_)
    out = pl.pallas_call(
        functools.partial(_qsq_dense_kernel, group=group),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, kp), lambda i, j: (i, 0)),
            pl.BlockSpec((kp, bn_), lambda i, j: (0, j)),
            pl.BlockSpec((kp // group, bn_), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, cp, sp)
    return out[:m, :n]
