"""Deterministic synthetic datasets (DESIGN.md §2 substitution).

The paper evaluates on MNIST and CIFAR-10, which are network downloads this
environment does not have.  We substitute two seeded, procedurally generated
datasets with identical tensor shapes and the same 10-class CNN task:

- ``synth_mnist``  — 28x28x1 grayscale digits rendered from per-digit stroke
  skeletons with random affine jitter, stroke width, and noise.
- ``synth_cifar``  — 32x32x3 colour composites: 10 classes defined by
  (colour family, shape, texture) with jitter and noise; several class pairs
  share attributes so the task is non-trivial and quantization damage is
  visible (the paper's ConvNet sits at 68–73 %).

The rust side has an independent generator for *request* traffic
(`rust/src/data/`); evaluation always uses the .npy sets written here so both
languages score the same examples.
"""

from __future__ import annotations

import numpy as np

# ---------------------------------------------------------------------------
# synth-mnist: stroke skeletons in [0,1]^2, y axis points down.
# ---------------------------------------------------------------------------


def _arc(cx, cy, r, a0, a1, n=24):
    t = np.linspace(a0, a1, n)
    return np.stack([cx + r * np.cos(t), cy + r * np.sin(t)], axis=1)


def _line(x0, y0, x1, y1, n=16):
    t = np.linspace(0.0, 1.0, n)
    return np.stack([x0 + (x1 - x0) * t, y0 + (y1 - y0) * t], axis=1)


def _digit_strokes(d: int) -> np.ndarray:
    """Polyline point cloud for digit d, as [P, 2] points in [0,1]^2."""
    pi = np.pi
    if d == 0:
        return _arc(0.5, 0.5, 0.30, 0, 2 * pi, 48)
    if d == 1:
        return np.concatenate([_line(0.5, 0.15, 0.5, 0.85), _line(0.38, 0.28, 0.5, 0.15)])
    if d == 2:
        return np.concatenate(
            [_arc(0.5, 0.33, 0.22, -pi, 0.25 * pi, 28), _line(0.65, 0.45, 0.3, 0.82), _line(0.3, 0.82, 0.72, 0.82)]
        )
    if d == 3:
        return np.concatenate(
            [_arc(0.48, 0.32, 0.18, -pi * 0.9, pi * 0.5, 24), _arc(0.48, 0.66, 0.20, -pi * 0.5, pi * 0.9, 24)]
        )
    if d == 4:
        return np.concatenate(
            [_line(0.62, 0.15, 0.62, 0.85), _line(0.62, 0.15, 0.3, 0.6), _line(0.3, 0.6, 0.78, 0.6)]
        )
    if d == 5:
        return np.concatenate(
            [_line(0.68, 0.18, 0.35, 0.18), _line(0.35, 0.18, 0.33, 0.47), _arc(0.5, 0.63, 0.2, -pi * 0.6, pi * 0.75, 28)]
        )
    if d == 6:
        return np.concatenate([_arc(0.5, 0.62, 0.22, 0, 2 * pi, 32), _arc(0.62, 0.35, 0.35, pi * 0.6, pi * 1.05, 20)])
    if d == 7:
        return np.concatenate([_line(0.28, 0.18, 0.72, 0.18), _line(0.72, 0.18, 0.42, 0.85)])
    if d == 8:
        return np.concatenate([_arc(0.5, 0.33, 0.17, 0, 2 * pi, 28), _arc(0.5, 0.67, 0.21, 0, 2 * pi, 28)])
    if d == 9:
        return np.concatenate([_arc(0.5, 0.38, 0.22, 0, 2 * pi, 32), _line(0.7, 0.42, 0.6, 0.85)])
    raise ValueError(d)


_DIGITS = [_digit_strokes(d) for d in range(10)]
_GRID28 = np.stack(np.meshgrid(np.arange(28), np.arange(28), indexing="ij"), axis=-1).reshape(-1, 2)


def _render_digit(d: int, rng: np.random.Generator) -> np.ndarray:
    pts = _DIGITS[d].copy()
    # random affine: rotation, scale, shear, translation (about the center)
    th = rng.uniform(-0.38, 0.38)
    sx = rng.uniform(0.72, 1.22)
    sy = rng.uniform(0.72, 1.22)
    sh = rng.uniform(-0.22, 0.22)
    rot = np.array([[np.cos(th), -np.sin(th)], [np.sin(th), np.cos(th)]])
    aff = rot @ np.array([[sx, sh], [0.0, sy]])
    pts = (pts - 0.5) @ aff.T + 0.5 + rng.uniform(-0.1, 0.1, size=2)
    # random per-point wobble (stroke irregularity) and dropout (broken strokes)
    pts = pts + rng.normal(0, 0.012, pts.shape)
    keep = rng.random(len(pts)) > 0.12
    if keep.sum() > 8:
        pts = pts[keep]
    pix = pts * 27.0  # to pixel coords (x right, y down) -> grid is (row, col)
    pix = pix[:, ::-1]
    width = rng.uniform(0.55, 1.5)
    d2 = ((_GRID28[:, None, :] - pix[None, :, :]) ** 2).sum(axis=2)
    img = np.exp(-d2.min(axis=1) / (2.0 * width**2)).reshape(28, 28)
    # distractor clutter: a few random blobs
    for _ in range(rng.integers(0, 3)):
        cy, cx = rng.uniform(2, 26, 2)
        r = rng.uniform(0.6, 1.4)
        dd = ((_GRID28[:, 0] - cy) ** 2 + (_GRID28[:, 1] - cx) ** 2).reshape(28, 28)
        img = np.maximum(img, rng.uniform(0.3, 0.7) * np.exp(-dd / (2 * r * r)))
    contrast = rng.uniform(0.45, 1.0)
    img = np.clip(img * contrast + rng.normal(0, 0.13, (28, 28)), 0.0, 1.0)
    return img.astype(np.float32)


def synth_mnist(n: int, seed: int = 0):
    """n images -> (x [n,28,28,1] f32 in [0,1], y [n] int32)."""
    rng = np.random.default_rng(seed)
    y = rng.integers(0, 10, size=n).astype(np.int32)
    x = np.stack([_render_digit(int(d), rng) for d in y])[..., None]
    return x.astype(np.float32), y


# ---------------------------------------------------------------------------
# synth-cifar: (colour, shape, texture) composites.
# ---------------------------------------------------------------------------

# class -> (rgb base colour, shape, texture)
_CIFAR_CLASSES = [
    ((0.85, 0.15, 0.15), "circle", "flat"),  # 0 red circle
    ((0.95, 0.35, 0.10), "circle", "flat"),  # 1 orange circle (confusable w/ 0)
    ((0.15, 0.70, 0.20), "square", "flat"),  # 2 green square
    ((0.15, 0.45, 0.85), "square", "stripes"),  # 3 blue striped square
    ((0.80, 0.20, 0.80), "triangle", "flat"),  # 4 magenta triangle
    ((0.90, 0.85, 0.20), "triangle", "checker"),  # 5 yellow checker triangle
    ((0.20, 0.80, 0.80), "ring", "flat"),  # 6 cyan ring
    ((0.55, 0.30, 0.85), "ring", "stripes"),  # 7 purple striped ring (confusable w/ 6)
    ((0.90, 0.90, 0.90), "cross", "flat"),  # 8 white cross
    ((0.55, 0.55, 0.55), "cross", "checker"),  # 9 gray checker cross
]

_GRID32 = np.stack(np.meshgrid(np.linspace(0, 1, 32), np.linspace(0, 1, 32), indexing="ij"), axis=-1)


def _shape_mask(shape: str, rng: np.random.Generator) -> np.ndarray:
    cy, cx = 0.5 + rng.uniform(-0.12, 0.12, 2)
    r = rng.uniform(0.2, 0.3)
    yy, xx = _GRID32[..., 0], _GRID32[..., 1]
    if shape == "circle":
        return (((yy - cy) ** 2 + (xx - cx) ** 2) < r * r).astype(np.float32)
    if shape == "ring":
        d2 = (yy - cy) ** 2 + (xx - cx) ** 2
        return ((d2 < r * r) & (d2 > (0.55 * r) ** 2)).astype(np.float32)
    if shape == "square":
        return ((np.abs(yy - cy) < r) & (np.abs(xx - cx) < r)).astype(np.float32)
    if shape == "triangle":
        return ((yy - cy + r > 0) & (yy - cy < 2 * (xx - cx) + r) & (yy - cy < -2 * (xx - cx) + r)).astype(np.float32)
    if shape == "cross":
        w = 0.4 * r
        return ((np.abs(yy - cy) < w) & (np.abs(xx - cx) < r) | (np.abs(xx - cx) < w) & (np.abs(yy - cy) < r)).astype(
            np.float32
        )
    raise ValueError(shape)


def _texture(tex: str, rng: np.random.Generator) -> np.ndarray:
    yy, xx = _GRID32[..., 0], _GRID32[..., 1]
    if tex == "flat":
        return np.ones((32, 32), np.float32)
    if tex == "stripes":
        f = rng.uniform(8, 12)
        ph = rng.uniform(0, 2 * np.pi)
        return (0.6 + 0.4 * np.sign(np.sin(2 * np.pi * f * xx + ph))).astype(np.float32)
    if tex == "checker":
        f = rng.uniform(4, 6)
        return (0.6 + 0.4 * np.sign(np.sin(2 * np.pi * f * xx) * np.sin(2 * np.pi * f * yy))).astype(np.float32)
    raise ValueError(tex)


def _render_cifar(cls: int, rng: np.random.Generator) -> np.ndarray:
    rgb, shape, tex = _CIFAR_CLASSES[cls]
    # busy background: gradient + random colour blobs (clutter)
    g0 = rng.uniform(0.0, 0.5, 3)
    g1 = rng.uniform(0.0, 0.5, 3)
    t = _GRID32[..., 0:1]
    bg = g0 * (1 - t) + g1 * t
    yy, xx = _GRID32[..., 0], _GRID32[..., 1]
    for _ in range(rng.integers(1, 4)):
        cy, cx = rng.uniform(0, 1, 2)
        r = rng.uniform(0.08, 0.2)
        blob = np.exp(-((yy - cy) ** 2 + (xx - cx) ** 2) / (2 * r * r))[..., None]
        bg = bg * (1 - 0.6 * blob) + 0.6 * blob * rng.uniform(0.1, 0.8, 3)
    mask = _shape_mask(shape, rng)[..., None]
    texm = _texture(tex, rng)[..., None]
    # heavy colour jitter pushes the confusable class pairs together
    colour = np.clip(np.array(rgb) * rng.uniform(0.6, 1.3, 3) + rng.uniform(-0.12, 0.12, 3), 0, 1.3)
    strength = rng.uniform(0.55, 1.0)  # low-contrast foregrounds
    img = bg * (1 - strength * mask) + strength * mask * texm * colour
    # occlusion bar
    if rng.random() < 0.4:
        o0 = rng.integers(0, 26)
        img[o0 : o0 + rng.integers(3, 7), :, :] *= rng.uniform(0.2, 0.6)
    img = img + rng.normal(0, 0.16, (32, 32, 3))
    return np.clip(img, 0.0, 1.0).astype(np.float32)


def synth_cifar(n: int, seed: int = 0):
    """n images -> (x [n,32,32,3] f32 in [0,1], y [n] int32)."""
    rng = np.random.default_rng(seed)
    y = rng.integers(0, 10, size=n).astype(np.int32)
    x = np.stack([_render_cifar(int(c), rng) for c in y])
    return x.astype(np.float32), y
