"""Quality Scalable Quantization — numpy implementation (eqs. 5–10).

This is the build-time quantizer used by ``aot.py`` to produce the quantized
artifacts and by pytest as a mirror of the rust runtime quantizer
(``rust/src/quant/qsq.rs``).  Both sides share the layout convention below
and are pinned against each other through parity vectors written to
``artifacts/parity/``.

Layout convention (shared with rust — keep in sync!):
  * A weight tensor is quantized in its *matmul layout* ``[K, OC]`` (conv
    weights ``[kh,kw,C,OC]`` are reshaped to ``[kh*kw*C, OC]`` with the
    (di, dj, c) row ordering of ``ref.im2col``).
  * Grouping is along K in contiguous runs of ``group`` rows per output
    column: vector ``v = w[k0:k0+group, oc]``.  With ``group == C`` this is
    exactly the paper's channel-wise vector (Fig. 5); ``group == K`` is
    filter-wise (Fig. 6).
  * Codes are Table-II values 0..6 stored one per int8; ``scalars`` has shape
    ``[K/group, OC]`` (f32).

Canonicalized assignment rule (DESIGN.md §6): per-sign MLE sigma, thresholds
(gamma*sigma, sigma, delta*sigma), levels limited by phi in {1,2,4};
(gamma, delta) found by exhaustive grid search minimizing eq. 5, per tensor.
"""

from __future__ import annotations

import dataclasses
import warnings

import numpy as np

# Table II: code -> level multiplier (index = code).
LUT = np.array([0.0, 1.0, 2.0, 4.0, -1.0, -2.0, -4.0, 0.0], dtype=np.float32)
# level magnitude -> positive code
_CODE_OF_LEVEL = {0.0: 0, 1.0: 1, 2.0: 2, 4.0: 3}

GAMMA_GRID = np.round(np.arange(0.05, 1.00, 0.05), 4)
DELTA_GRID = np.array([1.1, 1.25, 1.5, 1.75, 2.0, 2.25, 2.5, 3.0])


def levels_for_phi(phi: int) -> np.ndarray:
    if phi == 1:
        return np.array([0.0, 1.0], dtype=np.float32)
    if phi == 2:
        return np.array([0.0, 1.0, 2.0], dtype=np.float32)
    if phi == 4:
        return np.array([0.0, 1.0, 2.0, 4.0], dtype=np.float32)
    raise ValueError(f"phi must be in {{1,2,4}}, got {phi}")


def code_bits(phi: int) -> int:
    """Eq. 8 (canonicalized): bits for one weight's code at quality phi.

    Level count is 2*(1+log2(phi))+1 (zero plus +/- each power of two up to
    phi); bits = ceil(log2(levels)).  The paper's printed eq. 8 puts the +1
    outside the log and yields 4 bits for phi=4, contradicting its own
    "3-bit encoding" claim — we keep the version consistent with Table II:
    phi=1 -> 2 bits, phi=2 -> 3 bits, phi=4 -> 3 bits.
    """
    levels = 2 * (1 + int(np.log2(phi))) + 1
    return int(np.ceil(np.log2(levels)))


@dataclasses.dataclass
class QuantizedTensor:
    """One quantized weight tensor (matmul layout)."""

    codes: np.ndarray  # int8 [K, OC], Table-II codes
    scalars: np.ndarray  # f32 [K/group, OC]
    group: int
    phi: int
    gamma: float
    delta: float
    shape: tuple  # original tensor shape

    def decode(self) -> np.ndarray:
        """Shift-and-scale decode (Table II) back to the original shape."""
        lvl = LUT[self.codes.astype(np.int32)]
        alpha = np.repeat(self.scalars, self.group, axis=0)
        return (lvl * alpha).reshape(self.shape).astype(np.float32)


def to_matrix(w: np.ndarray) -> np.ndarray:
    """Tensor -> matmul layout [K, OC]. 2-D passes through; 4-D conv reshapes."""
    if w.ndim == 2:
        return w
    if w.ndim == 4:
        kh, kw, c, oc = w.shape
        return w.reshape(kh * kw * c, oc)
    raise ValueError(f"unsupported ndim {w.ndim}")


def _group_stats(vg: np.ndarray, phi: int):
    """Per-group alpha (eq. 9) and per-sign MLE sigma (eq. 7) with fallbacks.

    vg: [G, group, OC] grouped view.  Returns alpha, sig_p, sig_n each [G, OC].
    """
    absmean = np.abs(vg).mean(axis=1)
    alpha = absmean / phi
    pos = np.where(vg > 0, vg, np.nan)
    neg = np.where(vg < 0, -vg, np.nan)
    with np.errstate(invalid="ignore"), warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)  # empty sign sides
        sig_p = np.nanstd(pos, axis=1)
        sig_n = np.nanstd(neg, axis=1)
        mu_p = np.nanmean(pos, axis=1)
        mu_n = np.nanmean(neg, axis=1)
    # Fallback when a sign side is empty or degenerate: use the mean magnitude
    # of that side (or of the whole group) as the scale.
    fallback = np.where(absmean > 0, absmean, 1.0)
    sig_p = np.where(np.isnan(sig_p) | (sig_p <= 0), np.where(np.isnan(mu_p), fallback, np.maximum(mu_p, 1e-12)), sig_p)
    sig_n = np.where(np.isnan(sig_n) | (sig_n <= 0), np.where(np.isnan(mu_n), fallback, np.maximum(mu_n, 1e-12)), sig_n)
    return alpha, sig_p, sig_n


def _assign_sigma(vg, alpha, sig_p, sig_n, phi, gamma, delta):
    """Eq.-10 (canonicalized) code assignment. vg [G, group, OC] -> codes."""
    sig = np.where(vg >= 0, sig_p[:, None, :], sig_n[:, None, :])
    mag = np.abs(vg)
    lvl = np.zeros_like(vg)
    lvl = np.where(mag >= gamma * sig, 1.0, lvl)
    if phi >= 2:
        lvl = np.where(mag >= sig, 2.0, lvl)
    if phi >= 4:
        lvl = np.where(mag >= delta * sig, 4.0, lvl)
    return np.sign(vg) * lvl


def _assign_nearest(vg, alpha, phi):
    """Ablation mode: nearest level in {0,±1α,±2α,±4α} (minimizes eq. 5)."""
    lv = levels_for_phi(phi)
    mag = np.abs(vg)
    # distances to each level magnitude
    d = np.abs(mag[..., None] - alpha[:, None, :, None] * lv.reshape(1, 1, 1, -1))
    idx = d.argmin(axis=-1)
    lvl = lv[idx]
    return np.sign(vg) * lvl


def _signed_level_to_code(slvl: np.ndarray) -> np.ndarray:
    mag = np.abs(slvl)
    base = np.zeros(slvl.shape, dtype=np.int8)
    for m, c in _CODE_OF_LEVEL.items():
        base = np.where(mag == m, np.int8(c), base)
    return np.where((slvl < 0) & (mag > 0), base + np.int8(3), base).astype(np.int8)


# Candidate multipliers for the alpha line-search ablation (mode="nearest-opt").
_ALPHA_MULTS = np.array([0.5, 0.75, 1.0, 1.5, 2.0, 2.5, 3.0, 4.0])


def quantize_matrix(
    w: np.ndarray,
    group: int,
    phi: int = 4,
    mode: str = "sigma-search",
    gamma: float | None = None,
    delta: float | None = None,
) -> QuantizedTensor:
    """Quantize w [K, OC] (or conv 4-D) with vectors of length ``group``.

    mode: "sigma-search" (paper: exhaustive gamma/delta search),
          "sigma" (fixed gamma/delta), "nearest" (ablation, optimal per eq. 5
          given the paper's eq.-9 alpha), "nearest-opt" (ablation: per-group
          line search over alpha — eq. 9 clamps everything above mean|w|,
          which is what collapses deep all-layer quantization; see DESIGN.md).
    """
    shape = w.shape
    wm = to_matrix(np.asarray(w, dtype=np.float32))
    k, oc = wm.shape
    assert k % group == 0, f"K={k} not divisible by group={group}"
    g = k // group
    vg = wm.reshape(g, group, oc)
    alpha, sig_p, sig_n = _group_stats(vg, phi)

    if mode == "nearest-opt":
        # per-group 1-D search over alpha multipliers, nearest-level assignment
        best_err = np.full((g, oc), np.inf)
        best_alpha = alpha.copy()
        best_slvl = np.zeros_like(vg)
        for m in _ALPHA_MULTS:
            a = alpha * m
            slvl = _assign_nearest(vg, a, phi)
            err = ((vg - slvl * a[:, None, :]) ** 2).sum(axis=1)
            upd = err < best_err
            best_err = np.where(upd, err, best_err)
            best_alpha = np.where(upd, a, best_alpha)
            best_slvl = np.where(upd[:, None, :], slvl, best_slvl)
        codes = _signed_level_to_code(best_slvl).reshape(k, oc)
        return QuantizedTensor(
            codes=codes, scalars=best_alpha.astype(np.float32), group=group,
            phi=phi, gamma=-1.0, delta=-1.0, shape=shape,
        )

    def encode_with(gam, dlt):
        if mode == "nearest":
            slvl = _assign_nearest(vg, alpha, phi)
        else:
            slvl = _assign_sigma(vg, alpha, sig_p, sig_n, phi, gam, dlt)
        recon = slvl * alpha[:, None, :]
        err = float(((vg - recon) ** 2).sum())
        return slvl, err

    if mode == "sigma-search":
        best = (None, np.inf, 0.5, 2.0)
        deltas = DELTA_GRID if phi >= 4 else np.array([2.0])
        for gam in GAMMA_GRID:
            for dlt in deltas:
                slvl, err = encode_with(gam, dlt)
                if err < best[1]:
                    best = (slvl, err, float(gam), float(dlt))
        slvl, _, gamma, delta = best
    elif mode == "sigma":
        gamma = 0.5 if gamma is None else gamma
        delta = 2.0 if delta is None else delta
        slvl, _ = encode_with(gamma, delta)
    elif mode == "nearest":
        gamma, delta = -1.0, -1.0
        slvl, _ = encode_with(0, 0)
    else:
        raise ValueError(mode)

    codes = _signed_level_to_code(slvl).reshape(k, oc)
    return QuantizedTensor(
        codes=codes,
        scalars=alpha.astype(np.float32),
        group=group,
        phi=phi,
        gamma=float(gamma),
        delta=float(delta),
        shape=shape,
    )


def quantization_error(w: np.ndarray, qt: QuantizedTensor) -> float:
    """Eq. 5 objective value (sum of squared reconstruction error)."""
    return float(((np.asarray(w, np.float32) - qt.decode()) ** 2).sum())


def zeros_fraction(qt: QuantizedTensor) -> float:
    return float((qt.codes == 0).mean())


def encoded_bits(qt: QuantizedTensor, fpb: int = 32) -> int:
    """Eq. 12: bits to store the encoded tensor (codes + scalars)."""
    be = code_bits(qt.phi)
    return int(qt.codes.size * be + qt.scalars.size * fpb)


def full_precision_bits(shape, fpb: int = 32) -> int:
    """Eq. 11: bits of the unquantized tensor."""
    return int(np.prod(shape)) * fpb
