"""L2: model forward graphs (LeNet-5, ConvNet-4) calling the L1 kernels.

Every forward takes the parameters *as arguments* so the rust coordinator can
feed full-precision, decoded-approximate, or CSD-projected weights into the
same compiled artifact.  The ``backend`` flag selects the compute path:

  backend="ref"    — pure-jnp oracles (training + tests; fast under XLA CPU)
  backend="pallas" — the L1 Pallas kernels (AOT artifacts; interpret=True)

Both paths are pinned equal by pytest, so the swap is sound (see
kernels/ref.py docstring).

Parameter layouts (NHWC, VALID convs; conv weights [kh,kw,C,OC]):

  LeNet-5 (28x28x1 -> 10), params = 10 tensors:
    c1w[5,5,1,6]  c1b[6]   -> relu -> pool2    (24->12)
    c2w[5,5,6,16] c2b[16]  -> relu -> pool2    (8->4)
    f1w[256,120]  f1b[120] -> relu
    f2w[120,84]   f2b[84]  -> relu             (= "features")
    f3w[84,10]    f3b[10]                      (full-precision head)

  ConvNet-4 (32x32x3 -> 10), params = 10 tensors, SAME 3x3 convs:
    k1[3,3,3,32] b1 -> relu -> pool (32->16)
    k2[3,3,32,32] b2 -> relu -> pool (16->8)
    k3[3,3,32,64] b3 -> relu -> pool (8->4)
    k4[3,3,64,64] b4 -> relu -> pool (4->2)
    fcw[256,10] fcb
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels import conv as kconv
from compile.kernels import csd as kcsd
from compile.kernels import qsq as kqsq
from compile.kernels import ref

LENET_PARAM_NAMES = ["c1w", "c1b", "c2w", "c2b", "f1w", "f1b", "f2w", "f2b", "f3w", "f3b"]
LENET_SHAPES = {
    "c1w": (5, 5, 1, 6),
    "c1b": (6,),
    "c2w": (5, 5, 6, 16),
    "c2b": (16,),
    "f1w": (256, 120),
    "f1b": (120,),
    "f2w": (120, 84),
    "f2b": (84,),
    "f3w": (84, 10),
    "f3b": (10,),
}
CONVNET_PARAM_NAMES = ["k1", "b1", "k2", "b2", "k3", "b3", "k4", "b4", "fcw", "fcb"]
CONVNET_SHAPES = {
    "k1": (3, 3, 3, 32),
    "b1": (32,),
    "k2": (3, 3, 32, 32),
    "b2": (32,),
    "k3": (3, 3, 32, 64),
    "b3": (64,),
    "k4": (3, 3, 64, 64),
    "b4": (64,),
    "fcw": (256, 10),
    "fcb": (10,),
}
# Tensors the QSQ pipeline quantizes (heads/biases stay fp32 — DESIGN.md §6).
LENET_QUANTIZED = ["c1w", "c2w", "f1w", "f2w"]
CONVNET_QUANTIZED = ["k1", "k2", "k3", "k4"]


def _mm(backend: str):
    return kconv.matmul if backend == "pallas" else ref.matmul


def _conv2d(x, w, backend: str):
    patches, oh, ow = ref.im2col(x, w.shape[0], w.shape[1])
    out = _mm(backend)(patches, w.reshape(-1, w.shape[3]))
    return out.reshape(x.shape[0], oh, ow, w.shape[3])


def _conv2d_same(x, w, backend: str):
    p = w.shape[0] // 2
    xp = jnp.pad(x, ((0, 0), (p, p), (p, p), (0, 0)))
    return _conv2d(xp, w, backend)


def _conv2d_qsq(x, codes, scalars, group, kh, kw, c, oc, backend: str):
    """Conv with QSQ-encoded weights: im2col then the fused decode+matmul."""
    patches, oh, ow = ref.im2col(x, kh, kw)
    if backend == "pallas":
        out = kqsq.qsq_dense(patches, codes, scalars, group)
    else:
        out = ref.qsq_dense(patches, codes, scalars, group)
    return out.reshape(x.shape[0], oh, ow, oc)


def lenet_fwd(x, params, backend: str = "ref"):
    """LeNet-5 forward: x [B,28,28,1] + 10 params -> logits [B,10]."""
    c1w, c1b, c2w, c2b, f1w, f1b, f2w, f2b, f3w, f3b = params
    h = jax.nn.relu(_conv2d(x, c1w, backend) + c1b)
    h = ref.maxpool2(h)
    h = jax.nn.relu(_conv2d(h, c2w, backend) + c2b)
    h = ref.maxpool2(h)
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(_mm(backend)(h, f1w) + f1b)
    h = jax.nn.relu(_mm(backend)(h, f2w) + f2b)
    return _mm(backend)(h, f3w) + f3b


def lenet_features(x, params, backend: str = "ref"):
    """Backbone up to the 84-d feature layer (input of the fp32 head)."""
    c1w, c1b, c2w, c2b, f1w, f1b, f2w, f2b = params[:8]
    h = jax.nn.relu(_conv2d(x, c1w, backend) + c1b)
    h = ref.maxpool2(h)
    h = jax.nn.relu(_conv2d(h, c2w, backend) + c2b)
    h = ref.maxpool2(h)
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(_mm(backend)(h, f1w) + f1b)
    return jax.nn.relu(_mm(backend)(h, f2w) + f2b)


def lenet_fwd_qsq(x, qargs, fp_params, groups, backend: str = "ref"):
    """LeNet with QSQ-encoded backbone weights, decoded in-graph (L1 kernel).

    qargs: (c1_codes, c1_scal, c2_codes, c2_scal, f1_codes, f1_scal,
            f2_codes, f2_scal) in matmul layout.
    fp_params: (c1b, c2b, f1b, f2b, f3w, f3b) full-precision leftovers.
    groups: dict name->group length (static).
    """
    c1c, c1s, c2c, c2s, f1c, f1s, f2c, f2s = qargs
    c1b, c2b, f1b, f2b, f3w, f3b = fp_params
    qd = kqsq.qsq_dense if backend == "pallas" else ref.qsq_dense
    h = jax.nn.relu(_conv2d_qsq(x, c1c, c1s, groups["c1w"], 5, 5, 1, 6, backend) + c1b)
    h = ref.maxpool2(h)
    h = jax.nn.relu(_conv2d_qsq(h, c2c, c2s, groups["c2w"], 5, 5, 6, 16, backend) + c2b)
    h = ref.maxpool2(h)
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(qd(h, f1c, f1s, groups["f1w"]) + f1b)
    h = jax.nn.relu(qd(h, f2c, f2s, groups["f2w"]) + f2b)
    mm = _mm(backend)
    return mm(h, f3w) + f3b


def convnet_fwd(x, params, backend: str = "ref"):
    """ConvNet-4 forward: x [B,32,32,3] + 10 params -> logits [B,10]."""
    k1, b1, k2, b2, k3, b3, k4, b4, fcw, fcb = params
    h = x
    for kw_, bw_ in ((k1, b1), (k2, b2), (k3, b3), (k4, b4)):
        h = jax.nn.relu(_conv2d_same(h, kw_, backend) + bw_)
        h = ref.maxpool2(h)
    h = h.reshape(h.shape[0], -1)
    return _mm(backend)(h, fcw) + fcb


def csd_dense_demo(x, w, digits: int = 3, backend: str = "pallas"):
    """Standalone CSD approximate-multiplier matmul (bench artifact)."""
    if backend == "pallas":
        return kcsd.csd_matmul(x, w, digits)
    return ref.csd_matmul(x, w, digits)


# ---------------------------------------------------------------------------
# Loss / training-step graphs
# ---------------------------------------------------------------------------


def softmax_xent(logits, y1h):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.sum(y1h * logp, axis=1))


def fc_step(feat, y1h, w, b, lr):
    """One SGD step on the fp32 head only (paper Table III: FC fine-tune).

    feat [B,84], y1h [B,10], w [84,10], b [10], lr scalar
    -> (loss, w', b').  AOT-compiled; the rust coordinator drives the loop.
    """

    def loss_fn(wb):
        return softmax_xent(ref.matmul(feat, wb[0]) + wb[1], y1h)

    loss, g = jax.value_and_grad(loss_fn)((w, b))
    return loss, w - lr * g[0], b - lr * g[1]


def init_params(shapes: dict, names, seed: int = 0):
    """He-init parameters in declaration order."""
    key = jax.random.PRNGKey(seed)
    out = []
    for n in names:
        shp = shapes[n]
        key, sub = jax.random.split(key)
        if len(shp) == 1:
            out.append(jnp.zeros(shp, jnp.float32))
        else:
            fan_in = int(jnp.prod(jnp.array(shp[:-1])))
            out.append(jax.random.normal(sub, shp, jnp.float32) * jnp.sqrt(2.0 / fan_in))
    return out
