"""AOT entry point: train -> quantize parity vectors -> lower HLO artifacts.

Emits HLO **text** (NOT ``lowered.compile()`` / ``.serialize()``): jax >= 0.5
serializes HloModuleProto with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the rust ``xla`` crate) rejects; the HLO text
parser reassigns ids and round-trips cleanly.  See /opt/xla-example/README.md.

Outputs under ``artifacts/``:
  *.hlo.txt                 — one per compiled entry point
  manifest.json             — arg shapes/dtypes/order for the rust runtime
  weights/<model>/*.npy     — trained f32 parameters
  data/*.npy                — train/test splits
  parity/*                  — quantizer parity vectors for rust unit tests
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model
from compile import qsq_lib
from compile import train as trainer

# Group (vector length N) per quantized LeNet tensor in the fused artifact.
# Must divide K of the matmul layout: c1w K=25, c2w K=150, f1w K=256, f2w K=120.
LENET_QSQ_GROUPS = {"c1w": 5, "c2w": 6, "f1w": 16, "f2w": 8}

_DT = {"f32": jnp.float32, "i8": jnp.int8, "i32": jnp.int32}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _qsq_arg_shapes(groups: dict) -> list:
    """(name, shape, dtype) list for the QSQ-encoded LeNet backbone."""
    out = []
    for n in model.LENET_QUANTIZED:
        shp = model.LENET_SHAPES[n]
        k = int(np.prod(shp[:-1])) if len(shp) == 4 else shp[0]
        oc = shp[-1]
        g = groups[n]
        out.append((f"{n}_codes", (k, oc), "i8"))
        out.append((f"{n}_scalars", (k // g, oc), "f32"))
    return out


def artifact_defs() -> list:
    """Every AOT entry point: (name, fn, [(argname, shape, dtype)], meta)."""
    defs = []
    lenet_w = [(n, model.LENET_SHAPES[n], "f32") for n in model.LENET_PARAM_NAMES]
    convnet_w = [(n, model.CONVNET_SHAPES[n], "f32") for n in model.CONVNET_PARAM_NAMES]

    for b in (1, 32, 128):
        defs.append(
            dict(
                name=f"lenet_fwd_b{b}",
                fn=lambda x, *p: model.lenet_fwd(x, p, backend="ref"),
                args=[("x", (b, 28, 28, 1), "f32")] + lenet_w,
                meta={"model": "lenet", "batch": b, "kind": "fwd"},
            )
        )
        defs.append(
            dict(
                name=f"convnet_fwd_b{b}",
                fn=lambda x, *p: model.convnet_fwd(x, p, backend="ref"),
                args=[("x", (b, 32, 32, 3), "f32")] + convnet_w,
                meta={"model": "convnet", "batch": b, "kind": "fwd"},
            )
        )

    defs.append(
        dict(
            name="lenet_features_b128",
            fn=lambda x, *p: model.lenet_features(x, p, backend="ref"),
            args=[("x", (128, 28, 28, 1), "f32")] + lenet_w[:8],
            meta={"model": "lenet", "batch": 128, "kind": "features"},
        )
    )
    defs.append(
        dict(
            name="fc_step_b128",
            fn=model.fc_step,
            args=[
                ("feat", (128, 84), "f32"),
                ("y1h", (128, 10), "f32"),
                ("w", (84, 10), "f32"),
                ("b", (10,), "f32"),
                ("lr", (), "f32"),
            ],
            meta={"model": "lenet", "batch": 128, "kind": "fc_step"},
        )
    )

    qargs = _qsq_arg_shapes(LENET_QSQ_GROUPS)
    fp_names = ["c1b", "c2b", "f1b", "f2b", "f3w", "f3b"]
    fp_args = [(n, model.LENET_SHAPES[n], "f32") for n in fp_names]
    nq = len(qargs)

    def _mk_qsq(backend):
        def fn(x, *rest):
            q = rest[:nq]
            fp = rest[nq:]
            return model.lenet_fwd_qsq(x, q, fp, LENET_QSQ_GROUPS, backend=backend)

        return fn

    for backend, suffix in (("pallas", ""), ("ref", "_ref")):
        defs.append(
            dict(
                name=f"lenet_fwd_qsq{suffix}_b32",
                fn=_mk_qsq(backend),
                args=[("x", (32, 28, 28, 1), "f32")] + qargs + fp_args,
                meta={
                    "model": "lenet",
                    "batch": 32,
                    "kind": "fwd_qsq",
                    "backend": backend,
                    "groups": LENET_QSQ_GROUPS,
                    "quantized": model.LENET_QUANTIZED,
                    "fp_args": fp_names,
                },
            )
        )

    defs.append(
        dict(
            name="csd_matmul_demo",
            fn=lambda x, w: model.csd_dense_demo(x, w, digits=3, backend="pallas"),
            args=[("x", (256, 256), "f32"), ("w", (256, 256), "f32")],
            meta={"kind": "csd_demo", "digits": 3},
        )
    )
    return defs


def lower_all(out_dir: str, log=print) -> dict:
    manifest = {}
    for d in artifact_defs():
        specs = [jax.ShapeDtypeStruct(shape, _DT[dt]) for (_, shape, dt) in d["args"]]
        lowered = jax.jit(d["fn"]).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{d['name']}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        out_shapes = jax.eval_shape(d["fn"], *specs)
        if not isinstance(out_shapes, (tuple, list)):
            out_shapes = (out_shapes,)
        manifest[d["name"]] = {
            "file": fname,
            "args": [
                {"name": n, "shape": list(s), "dtype": dt} for (n, s, dt) in d["args"]
            ],
            "outputs": [
                {"shape": [int(v) for v in o.shape], "dtype": "f32"} for o in out_shapes
            ],
            "meta": d["meta"],
        }
        log(f"[aot] {d['name']}: {len(text)} chars, {len(d['args'])} args")
    return manifest


def write_parity(out_dir: str, log=print):
    """Quantizer parity vectors: rust `quant::qsq` must reproduce exactly."""
    pdir = os.path.join(out_dir, "parity")
    os.makedirs(pdir, exist_ok=True)
    rng = np.random.default_rng(42)
    w = (rng.standard_normal((24, 8)) * 0.1).astype(np.float32)
    np.save(os.path.join(pdir, "w.npy"), w)
    index = []
    for phi in (1, 2, 4):
        for mode in ("sigma-search", "nearest", "nearest-opt"):
            for group in (4, 8, 24):
                qt = qsq_lib.quantize_matrix(w, group=group, phi=phi, mode=mode)
                tag = f"phi{phi}_{mode.replace('-', '')}_g{group}"
                np.save(os.path.join(pdir, f"codes_{tag}.npy"), qt.codes)
                np.save(os.path.join(pdir, f"scalars_{tag}.npy"), qt.scalars)
                np.save(os.path.join(pdir, f"decoded_{tag}.npy"), qt.decode())
                index.append(
                    {
                        "tag": tag,
                        "phi": phi,
                        "mode": mode,
                        "group": group,
                        "gamma": qt.gamma,
                        "delta": qt.delta,
                        "error": qsq_lib.quantization_error(w, qt),
                    }
                )
    with open(os.path.join(pdir, "index.json"), "w") as f:
        json.dump(index, f, indent=1)
    log(f"[aot] parity vectors: {len(index)} cases")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifacts dir")
    ap.add_argument("--skip-train", action="store_true", help="reuse existing weights/data")
    args = ap.parse_args()
    out = os.path.abspath(args.out)
    os.makedirs(out, exist_ok=True)

    metrics = {}
    have_weights = os.path.exists(os.path.join(out, "weights", "convnet", "fcb.npy"))
    if args.skip_train and have_weights:
        print("[aot] --skip-train: reusing existing weights/data")
        mpath = os.path.join(out, "manifest.json")
        if os.path.exists(mpath):
            with open(mpath) as f:
                metrics = json.load(f).get("metrics", {})
    else:
        metrics = trainer.save_all(out)

    manifest = lower_all(out)
    write_parity(out)
    payload = {
        "version": 1,
        "artifacts": manifest,
        "metrics": metrics,
        "models": {
            "lenet": {
                "params": model.LENET_PARAM_NAMES,
                "shapes": {n: list(model.LENET_SHAPES[n]) for n in model.LENET_PARAM_NAMES},
                "quantized": model.LENET_QUANTIZED,
                "qsq_groups": LENET_QSQ_GROUPS,
                "dataset": "mnist",
            },
            "convnet": {
                "params": model.CONVNET_PARAM_NAMES,
                "shapes": {n: list(model.CONVNET_SHAPES[n]) for n in model.CONVNET_PARAM_NAMES},
                "quantized": model.CONVNET_QUANTIZED,
                "dataset": "cifar",
            },
        },
    }
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(payload, f, indent=1)
    print(f"[aot] wrote manifest with {len(manifest)} artifacts to {out}")


if __name__ == "__main__":
    main()
